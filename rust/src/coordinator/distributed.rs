//! Distributed coordinator: leader thread + N agent worker threads
//! exchanging *serialized wire frames* through byte-counted transports.
//!
//! This is the deployment-shaped variant of [`super::engine::Engine`]:
//! each agent runs in its own OS thread with its own model replica,
//! compute backend (PureRust — PJRT handles are not Send), and its own
//! [`Strategy`](crate::algo::Strategy) instance (client-side state such
//! as error-feedback residuals lives with the agent, exactly as it would
//! in a real deployment). Each round the leader's [`Sampler`] selects the
//! active set (partial participation included) and unicasts a
//! [`super::wire::WireRoundPlan`] frame plus the
//! [`super::wire::WireModel`] broadcast to those workers only; a worker
//! runs the local stage its strategy declares and sends back the
//! strategy-encoded uplink frame. The leader decodes through its own
//! strategy instance, drops deadline casualties per the [`SimNet`]
//! report, aggregates, applies, and evaluates — no method dispatch
//! anywhere in this file. Each casualty then receives a
//! [`super::wire::WireNack`] delivery-feedback frame, on which the
//! worker's strategy rolls back its delivery-assuming encode state
//! ([`crate::algo::Strategy::on_dropped`]) — mirroring the sequential
//! engine's in-process `on_dropped` calls client for client.
//!
//! Given the same config and run seed, FedScalar/FedAvg training metrics
//! are bit-identical to the sequential engine (asserted by the
//! integration suite): same shards, same batch streams, same seeds, same
//! arithmetic — serialization is exact for f32. (QSGD differs only in the
//! stochastic-rounding stream: per-worker strategies draw independently.)

use crate::algo::{LocalStage, Strategy};
use crate::config::ExperimentConfig;
use crate::coordinator::client::ClientState;
use crate::coordinator::engine::load_data;
use crate::coordinator::messages::Uplink;
use crate::coordinator::transport::{duplex, AgentEndpoint, LeaderEndpoint};
use crate::coordinator::wire::{WireModel, WireNack, WireRoundPlan};
use crate::error::{Error, Result};
use crate::metrics::{RoundRecord, RunHistory};
use crate::nn::ModelSpec;
use crate::rng::SplitMix64;
use crate::runtime::{Backend, PureRustBackend};
use crate::simnet::{Sampler, SimNet};
use crate::{log_debug, log_info};
use std::sync::Arc;
use std::time::Instant;

/// Orders from leader to workers (frames are models; control is in-proc).
enum Control {
    /// Run round k against the frame that follows on the downlink.
    Round,
    /// A delivery NACK frame follows on the downlink: the worker's last
    /// upload was dropped; its strategy must roll back delivery-assuming
    /// state ([`Strategy::on_dropped`]).
    Nack,
    /// Shut down.
    Stop,
}

struct WorkerHandle {
    endpoint: LeaderEndpoint,
    control: std::sync::mpsc::Sender<Control>,
    /// Telemetry side-channel (NOT wire): per-round client loss.
    telemetry: std::sync::mpsc::Receiver<f32>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// The distributed (threaded, frame-passing) federated engine.
pub struct DistributedEngine {
    cfg: ExperimentConfig,
    workers: Vec<WorkerHandle>,
    leader_backend: PureRustBackend,
    /// Leader-side strategy instance (decode + aggregate + accounting).
    strategy: Box<dyn Strategy>,
    /// Leader-side scenario simulator + selection — the SAME seed
    /// derivations as the sequential engine, so both engines pick (and
    /// drop) identical clients every round.
    simnet: SimNet,
    sampler: Sampler,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
    params: Vec<f32>,
    cum_bits: f64,
    cum_downlink_bits: f64,
    cum_sim_seconds: f64,
    cum_energy_joules: f64,
    history: RunHistory,
}

impl DistributedEngine {
    pub fn from_config(cfg: &ExperimentConfig, run_seed: u64) -> Result<DistributedEngine> {
        cfg.validate()?;
        let (train, test) = load_data(cfg)?;
        let train = Arc::new(train);
        let partition = match cfg.dirichlet_alpha {
            None => crate::data::iid_partition(train.len(), cfg.fed.num_agents, run_seed),
            Some(a) => crate::data::dirichlet_partition(&train, cfg.fed.num_agents, a, run_seed),
        };
        if partition.min_shard() == 0 {
            return Err(Error::config("a client received an empty shard"));
        }

        let mut leader_backend = PureRustBackend::new(&cfg.model);
        leader_backend.set_shape(cfg.fed.local_steps, cfg.fed.batch_size);
        let params = leader_backend.init_params(SplitMix64::derive(run_seed, 0xd0d0))?;
        // the leader's decode/aggregate stage parallelizes exactly like
        // the sequential engine's (fed.threads semantics shared); pooled
        // reductions are bit-identical to serial, so this cannot perturb
        // the cross-engine equality the tests pin
        let threads = crate::coordinator::engine::resolve_threads(cfg.fed.threads);
        if threads > 1 {
            leader_backend.set_worker_pool(Arc::new(crate::runtime::WorkerPool::new(threads)));
        }

        let mut workers = Vec::with_capacity(cfg.fed.num_agents);
        for (id, shard) in partition.shards.iter().enumerate() {
            workers.push(spawn_worker(
                id,
                cfg,
                train.clone(),
                shard.clone(),
                run_seed,
            ));
        }

        Ok(DistributedEngine {
            history: RunHistory::new(cfg.fed.method.name()),
            simnet: SimNet::new(
                &cfg.network,
                &cfg.scenario,
                cfg.model.param_dim(),
                cfg.fed.num_agents,
                run_seed,
            ),
            sampler: Sampler::new(cfg.sampler_policy(), run_seed),
            strategy: cfg.fed.method.instantiate(run_seed),
            leader_backend,
            test_x: test.x,
            test_y: test.y,
            params,
            cum_bits: 0.0,
            cum_downlink_bits: 0.0,
            cum_sim_seconds: 0.0,
            cum_energy_joules: 0.0,
            workers,
            cfg: cfg.clone(),
        })
    }

    /// Run all K rounds.
    pub fn run(&mut self) -> Result<RunHistory> {
        let rounds = self.cfg.fed.rounds;
        log_info!(
            "distributed run: method={} workers={} K={}",
            self.cfg.fed.method.name(),
            self.workers.len(),
            rounds
        );
        for k in 0..rounds {
            let eval = k % self.cfg.fed.eval_every == 0 || k + 1 == rounds;
            self.run_round(k, eval)?;
        }
        self.shutdown();
        Ok(self.history.clone())
    }

    fn run_round(&mut self, k: usize, eval: bool) -> Result<()> {
        let host_t0 = Instant::now();
        // select this round's active set (leader-side, identical to the
        // sequential engine's sampler stream)
        let avail = self.simnet.available(k as u64);
        let active = self.sampler.select(&avail, self.simnet.profiles());
        if active.is_empty() {
            if eval {
                self.push_record(k, f64::NAN, host_t0)?;
            }
            return Ok(());
        }
        // unicast the round plan + model frame to the selected workers
        // only (an unselected worker never hears the round and keeps its
        // batch/seed streams untouched, exactly like the sequential
        // engine's inactive clients)
        let plan = WireRoundPlan {
            round: k as u32,
            active: active.iter().map(|&c| c as u32).collect(),
        }
        .encode();
        let frame = WireModel {
            round: k as u32,
            params: self.params.clone(),
        }
        .encode();
        for &c in &active {
            let w = &self.workers[c];
            w.control
                .send(Control::Round)
                .map_err(|_| Error::invariant("worker died"))?;
            w.endpoint
                .downlink
                .send(plan.clone())
                .map_err(Error::invariant)?;
            w.endpoint
                .downlink
                .send(frame.clone())
                .map_err(Error::invariant)?;
        }
        // collect uplink frames (in active order — determinism); the
        // transport's frame-byte counters remain available for the
        // framing-inclusive view
        let mut uplinks: Vec<Uplink> = Vec::with_capacity(active.len());
        let mut losses = Vec::with_capacity(active.len());
        for &c in &active {
            let w = &self.workers[c];
            let bytes = w.endpoint.uplink.recv().map_err(Error::invariant)?;
            uplinks.push(self.strategy.wire_decode(&bytes)?);
            losses.push(
                w.telemetry
                    .recv()
                    .map_err(|_| Error::invariant("telemetry lost"))?,
            );
        }
        // netsim lifecycle: the strategy's nominal payload accounting is
        // the single source of truth both engines charge
        let up_bits = self.strategy.uplink_bits(self.params.len());
        let down_bits = self.strategy.downlink_bits(self.params.len());
        let report = self.simnet.run_round(&active, up_bits, down_bits);
        self.cum_bits += report.uplink_bits as f64;
        self.cum_downlink_bits += report.downlink_bits as f64;
        self.cum_sim_seconds += report.round_seconds;
        self.cum_energy_joules += report.energy_joules;

        // aggregate + apply the survivors (loss telemetry is not on the
        // wire, so the round loss comes from the side channel — over the
        // same survivor set the sequential engine averages)
        let survivors: Vec<Uplink> = report.filter_survivors(uplinks);
        let train_loss = if survivors.is_empty() {
            crate::algo::strategy::mean_loss_f32(&losses)
        } else {
            self.strategy.aggregate_and_apply(
                &mut self.leader_backend,
                &mut self.params,
                &survivors,
            )?;
            // same survivor set, same summation (mean_loss_f32) as the
            // sequential engine's mean_loss over survivor uplinks —
            // loss telemetry is not on the wire, so it comes from the
            // side channel
            crate::algo::strategy::mean_loss_f32(&report.filter_survivors(losses))
        };

        // delivery feedback: NACK every casualty so its worker-side
        // strategy rolls back delivery-assuming encode state (Top-k
        // residuals), exactly as the sequential engine's in-process
        // `on_dropped` calls do — same clients, same active order. The
        // leader's own strategy instance holds no client-side state in
        // this engine, so the rollback happens only where the state
        // lives: on the worker.
        if !report.all_completed() {
            for (i, &c) in active.iter().enumerate() {
                if report.outcome[i].delivered() {
                    continue;
                }
                let w = &self.workers[c];
                w.control
                    .send(Control::Nack)
                    .map_err(|_| Error::invariant("worker died"))?;
                let nack = WireNack {
                    round: k as u32,
                    client: c as u32,
                };
                w.endpoint
                    .downlink
                    .send(nack.encode())
                    .map_err(Error::invariant)?;
            }
        }

        if eval {
            log_debug!(
                "dist round {k}: loss={train_loss:.4} active={} dropped={}",
                active.len(),
                report.dropped
            );
            self.push_record(k, train_loss, host_t0)?;
        }
        Ok(())
    }

    /// Evaluate and append one history record at the current counters.
    fn push_record(&mut self, k: usize, train_loss: f64, host_t0: Instant) -> Result<()> {
        let (test_loss, test_acc) =
            self.leader_backend
                .evaluate(&self.params, &self.test_x, &self.test_y)?;
        self.history.push(RoundRecord {
            round: k,
            train_loss,
            test_loss: test_loss as f64,
            test_acc: test_acc as f64,
            cum_bits: self.cum_bits,
            cum_downlink_bits: self.cum_downlink_bits,
            cum_sim_seconds: self.cum_sim_seconds,
            cum_energy_joules: self.cum_energy_joules,
            host_ms: host_t0.elapsed().as_secs_f64() * 1e3,
        });
        Ok(())
    }

    /// Current global model (for inspection / checkpointing).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Step one round manually (used by tests and the checkpoint resume).
    pub fn step(&mut self, k: usize, eval: bool) -> Result<()> {
        self.run_round(k, eval)
    }

    /// Total bytes that crossed the uplinks (frames, incl. framing).
    pub fn uplink_frame_bytes(&self) -> u64 {
        self.workers
            .iter()
            .map(|w| w.endpoint.up_stats.bytes())
            .sum()
    }

    /// Total bytes broadcast on the downlinks.
    pub fn downlink_frame_bytes(&self) -> u64 {
        self.workers
            .iter()
            .map(|w| w.endpoint.down_stats.bytes())
            .sum()
    }

    fn shutdown(&mut self) {
        for w in &self.workers {
            let _ = w.control.send(Control::Stop);
        }
        for w in self.workers.iter_mut() {
            if let Some(h) = w.join.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for DistributedEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn spawn_worker(
    id: usize,
    cfg: &ExperimentConfig,
    train: Arc<crate::data::Dataset>,
    shard: Vec<usize>,
    run_seed: u64,
) -> WorkerHandle {
    let (leader_ep, agent_ep) = duplex();
    let (ctl_tx, ctl_rx) = std::sync::mpsc::channel::<Control>();
    let (tel_tx, tel_rx) = std::sync::mpsc::channel::<f32>();
    let method = cfg.fed.method.clone();
    let (steps, batch, alpha) = (cfg.fed.local_steps, cfg.fed.batch_size, cfg.fed.alpha);
    let spec: ModelSpec = cfg.model.clone();
    let join = std::thread::spawn(move || {
        worker_main(
            id, agent_ep, ctl_rx, tel_tx, method, spec, train, shard, steps, batch, alpha,
            run_seed,
        );
    });
    WorkerHandle {
        endpoint: leader_ep,
        control: ctl_tx,
        telemetry: tel_rx,
        join: Some(join),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    id: usize,
    ep: AgentEndpoint,
    ctl: std::sync::mpsc::Receiver<Control>,
    telemetry: std::sync::mpsc::Sender<f32>,
    method: crate::algo::Method,
    spec: ModelSpec,
    train: Arc<crate::data::Dataset>,
    shard: Vec<usize>,
    steps: usize,
    batch: usize,
    alpha: f32,
    run_seed: u64,
) {
    let mut backend = PureRustBackend::new(&spec);
    backend.set_shape(steps, batch);
    let mut state = ClientState::new(id, train, shard, steps, batch, run_seed);
    // per-worker strategy instance with its own derived seed, so strategy
    // RNG streams (e.g. QSGD's stochastic rounding) are independent across
    // agents, and per-client state (error-feedback residuals) lives
    // client-side
    let mut strategy = method.instantiate(SplitMix64::derive(run_seed ^ 0x9594, id as u64));
    // the round this worker last uploaded for — the only round a NACK may
    // legitimately reference
    let mut last_round: Option<u32> = None;
    loop {
        match ctl.recv() {
            Ok(Control::Round) => {}
            Ok(Control::Nack) => {
                // delivery feedback: our last upload never landed — roll
                // back the strategy's delivery-assuming encode state
                let Ok(bytes) = ep.downlink.recv() else { return };
                let Ok(nack) = WireNack::decode(&bytes) else {
                    log_info!("worker {id}: undecodable NACK frame; shutting down");
                    return;
                };
                if nack.client as usize != id || Some(nack.round) != last_round {
                    log_info!(
                        "worker {id}: NACK for client {} round {} does not match \
                         this worker's last upload; shutting down",
                        nack.client,
                        nack.round
                    );
                    return;
                }
                if let Err(e) = strategy.on_dropped(id, nack.round as u64) {
                    log_info!("worker {id}: on_dropped failed ({e}); shutting down");
                    return;
                }
                // a send can only be NACKed once
                last_round = None;
                continue;
            }
            Ok(Control::Stop) | Err(_) => return,
        }
        // the round plan precedes the model frame; a worker only ever
        // receives rounds it was selected for, and the plan lets it
        // verify that (and learn its slot order) from the wire alone
        let Ok(plan_bytes) = ep.downlink.recv() else { return };
        let Ok(plan) = WireRoundPlan::decode(&plan_bytes) else {
            log_info!("worker {id}: undecodable round-plan frame; shutting down");
            return;
        };
        if !plan.active.iter().any(|&c| c as usize == id) {
            // a plan that excludes this worker is a protocol violation
            log_info!(
                "worker {id}: round {} plan excludes this worker; shutting down",
                plan.round
            );
            return;
        }
        last_round = Some(plan.round);
        let Ok(frame) = ep.downlink.recv() else { return };
        let Ok(model) = WireModel::decode(&frame) else { return };
        state.fill_round_batches(steps, batch);
        let stage = strategy.local_stage();
        let (up, loss) = match stage {
            LocalStage::Projected { dist, projections } => {
                let seed = state.next_projection_seed();
                let scalar = backend
                    .client_fedscalar(
                        &model.params,
                        &state.xb,
                        &state.yb,
                        seed,
                        alpha,
                        dist,
                        projections,
                    )
                    .expect("client stage");
                let loss = scalar.loss;
                (Uplink::Scalar(scalar), loss)
            }
            LocalStage::Delta => {
                let (delta, loss) = backend
                    .client_delta(&model.params, &state.xb, &state.yb, alpha)
                    .expect("client stage");
                let up = strategy
                    .encode_delta(id, delta, loss)
                    .expect("strategy encode");
                (up, loss)
            }
        };
        let bytes = strategy.wire_encode(&up).expect("wire encode");
        if ep.uplink.send(bytes).is_err() {
            return;
        }
        if telemetry.send(loss).is_err() {
            return;
        }
    }
}

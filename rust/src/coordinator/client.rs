//! Per-agent state: data shard, batch buffers, per-round seed stream.

use crate::data::{BatchSampler, Dataset};
use crate::rng::{SplitMix64, Xoshiro256};
use std::sync::Arc;

/// One federated agent as the coordinator sees it.
pub struct ClientState {
    /// Agent index `n` in `0..N`.
    pub id: usize,
    sampler: BatchSampler,
    seed_rng: Xoshiro256,
    /// [S, B, dim] batch features buffer (reused across rounds).
    pub xb: Vec<f32>,
    /// [S, B] batch labels buffer.
    pub yb: Vec<i32>,
}

impl ClientState {
    /// Build agent `id`'s state: shard sampler and seed stream derived
    /// from `run_seed`, batch buffers sized for `steps × batch`.
    pub fn new(
        id: usize,
        data: Arc<Dataset>,
        shard: Vec<usize>,
        steps: usize,
        batch: usize,
        run_seed: u64,
    ) -> Self {
        let dim = data.dim;
        ClientState {
            id,
            sampler: BatchSampler::new(data, shard, SplitMix64::derive(run_seed, id as u64)),
            seed_rng: Xoshiro256::seed_from(SplitMix64::derive(
                run_seed ^ 0x5eed_0000_0000_0006,
                id as u64,
            )),
            xb: vec![0.0; steps * batch * dim],
            yb: vec![0; steps * batch],
        }
    }

    /// Draw this round's S minibatches into the internal buffers.
    pub fn fill_round_batches(&mut self, steps: usize, batch: usize) {
        self.sampler
            .fill_local_batches(steps, batch, &mut self.xb, &mut self.yb);
    }

    /// Fresh 32-bit projection seed ξ_{k,n} for this round. Uniqueness
    /// across (round, agent) pairs is statistical (2^32 space), exactly as
    /// in the paper's protocol where each agent draws its own seed.
    pub fn next_projection_seed(&mut self) -> u32 {
        self.seed_rng.next_u32()
    }

    /// Number of samples in this agent's data shard.
    pub fn shard_len(&self) -> usize {
        self.sampler.shard_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn data() -> Arc<Dataset> {
        Arc::new(generate(
            &SyntheticConfig {
                n_per_class: 5,
                ..Default::default()
            },
            0,
        ))
    }

    #[test]
    fn seeds_differ_across_agents_and_rounds() {
        let ds = data();
        let mut a = ClientState::new(0, ds.clone(), vec![0, 1], 2, 4, 42);
        let mut b = ClientState::new(1, ds.clone(), vec![2, 3], 2, 4, 42);
        let s1 = a.next_projection_seed();
        let s2 = a.next_projection_seed();
        let s3 = b.next_projection_seed();
        assert_ne!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn deterministic_per_run_seed() {
        let ds = data();
        let mut a1 = ClientState::new(0, ds.clone(), vec![0, 1, 2], 2, 4, 7);
        let mut a2 = ClientState::new(0, ds.clone(), vec![0, 1, 2], 2, 4, 7);
        a1.fill_round_batches(2, 4);
        a2.fill_round_batches(2, 4);
        assert_eq!(a1.xb, a2.xb);
        assert_eq!(a1.yb, a2.yb);
        assert_eq!(a1.next_projection_seed(), a2.next_projection_seed());
        // different run seed -> different stream
        let mut a3 = ClientState::new(0, ds, vec![0, 1, 2], 2, 4, 8);
        a3.fill_round_batches(2, 4);
        assert_ne!(a1.xb, a3.xb);
    }

    #[test]
    fn buffers_sized_for_steps_batches() {
        let ds = data();
        let c = ClientState::new(0, ds, vec![0], 3, 8, 0);
        assert_eq!(c.xb.len(), 3 * 8 * 64);
        assert_eq!(c.yb.len(), 24);
        assert_eq!(c.shard_len(), 1);
    }
}

//! In-process transport: byte-counted duplex links between the leader and
//! each agent worker.
//!
//! The distributed engine ships *serialized frames* (coordinator::wire)
//! through these links, so its communication accounting is measured from
//! actual transmitted bytes rather than computed from a formula — the
//! formula ([`crate::algo::Method::uplink_bits`]) is then cross-checked
//! against the measurement in the tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Bytes-transferred counters for one direction of a link.
#[derive(Debug, Default)]
pub struct LinkStats {
    /// Frames put on the air.
    pub frames: AtomicU64,
    /// Total frame bytes put on the air.
    pub bytes: AtomicU64,
}

impl LinkStats {
    /// Count one transmitted frame of `len` bytes.
    pub fn record(&self, len: usize) {
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len as u64, Ordering::Relaxed);
    }

    /// Total bytes transmitted so far.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Total frames transmitted so far.
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }
}

/// Sending half of a byte-counted link.
pub struct FrameSender {
    tx: Sender<Vec<u8>>,
    stats: Arc<LinkStats>,
}

impl FrameSender {
    /// Transmit one frame, counting its bytes; errors if the peer hung up.
    pub fn send(&self, frame: Vec<u8>) -> Result<(), &'static str> {
        self.stats.record(frame.len());
        // byte 0 is the wire tag on every frame format, sealed or not
        crate::telemetry::frame_sent(frame.first().copied().unwrap_or(0), frame.len());
        self.tx.send(frame).map_err(|_| "peer hung up")
    }

    /// Record a transmission that never reaches the peer (a frame lost in
    /// flight): the radio spent the bytes, the link delivered nothing.
    /// Used by the fault layer's Drop fate so injected losses stay
    /// visible in the frame-byte accounting.
    pub fn transmit_void(&self, len: usize) {
        self.stats.record(len);
    }
}

/// Receiving half.
pub struct FrameReceiver {
    rx: Receiver<Vec<u8>>,
}

impl FrameReceiver {
    /// Block for the next frame; errors if the peer hung up.
    pub fn recv(&self) -> Result<Vec<u8>, &'static str> {
        self.rx.recv().map_err(|_| "peer hung up")
    }

    /// The next frame if one is already queued.
    pub fn try_recv(&self) -> Option<Vec<u8>> {
        self.rx.try_recv().ok()
    }

    /// Bounded receive: a hung or dead peer surfaces as an error within
    /// `timeout` instead of blocking the caller forever.
    pub fn recv_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Result<Vec<u8>, std::sync::mpsc::RecvTimeoutError> {
        self.rx.recv_timeout(timeout)
    }
}

/// One directed, byte-counted channel.
pub fn link() -> (FrameSender, FrameReceiver, Arc<LinkStats>) {
    let (tx, rx) = channel();
    let stats = Arc::new(LinkStats::default());
    (
        FrameSender {
            tx,
            stats: stats.clone(),
        },
        FrameReceiver { rx },
        stats,
    )
}

/// The leader's side of a full duplex connection to one agent.
pub struct LeaderEndpoint {
    /// Leader → agent sender.
    pub downlink: FrameSender,
    /// Agent → leader receiver.
    pub uplink: FrameReceiver,
    /// Downlink byte counters (shared with the sender).
    pub down_stats: Arc<LinkStats>,
    /// Uplink byte counters.
    pub up_stats: Arc<LinkStats>,
}

/// The agent's side.
pub struct AgentEndpoint {
    /// Leader → agent receiver.
    pub downlink: FrameReceiver,
    /// Agent → leader sender.
    pub uplink: FrameSender,
}

/// Create a duplex leader<->agent connection.
pub fn duplex() -> (LeaderEndpoint, AgentEndpoint) {
    let (d_tx, d_rx, d_stats) = link();
    let (u_tx, u_rx, u_stats) = link();
    (
        LeaderEndpoint {
            downlink: d_tx,
            uplink: u_rx,
            down_stats: d_stats,
            up_stats: u_stats,
        },
        AgentEndpoint {
            downlink: d_rx,
            uplink: u_tx,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_bytes_and_frames() {
        let (tx, rx, stats) = link();
        tx.send(vec![0u8; 13]).unwrap();
        tx.send(vec![0u8; 7]).unwrap();
        assert_eq!(rx.recv().unwrap().len(), 13);
        assert_eq!(rx.recv().unwrap().len(), 7);
        assert_eq!(stats.bytes(), 20);
        assert_eq!(stats.frames(), 2);
    }

    #[test]
    fn duplex_is_two_independent_links() {
        let (leader, agent) = duplex();
        leader.downlink.send(vec![1, 2, 3]).unwrap();
        assert_eq!(agent.downlink.recv().unwrap(), vec![1, 2, 3]);
        agent.uplink.send(vec![9]).unwrap();
        assert_eq!(leader.uplink.recv().unwrap(), vec![9]);
        assert_eq!(leader.down_stats.bytes(), 3);
        assert_eq!(leader.up_stats.bytes(), 1);
    }

    #[test]
    fn void_transmissions_count_without_delivering() {
        let (tx, rx, stats) = link();
        tx.transmit_void(9);
        tx.send(vec![0u8; 4]).unwrap();
        assert_eq!(stats.bytes(), 13);
        assert_eq!(stats.frames(), 2);
        assert_eq!(rx.recv().unwrap().len(), 4);
        assert!(rx.try_recv().is_none());
    }

    #[test]
    fn recv_timeout_bounds_the_wait() {
        use std::sync::mpsc::RecvTimeoutError;
        use std::time::Duration;
        let (tx, rx, _) = link();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Timeout)
        );
        tx.send(vec![1]).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(5)), Ok(vec![1]));
        drop(tx);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn hangup_detected() {
        let (tx, rx, _) = link();
        drop(rx);
        assert!(tx.send(vec![0]).is_err());
        let (tx2, rx2, _) = link();
        drop(tx2);
        assert!(rx2.recv().is_err());
    }

    #[test]
    fn cross_thread() {
        let (leader, agent) = duplex();
        let h = std::thread::spawn(move || {
            let got = agent.downlink.recv().unwrap();
            agent.uplink.send(got.iter().map(|b| b + 1).collect()).unwrap();
        });
        leader.downlink.send(vec![10, 20]).unwrap();
        assert_eq!(leader.uplink.recv().unwrap(), vec![11, 21]);
        h.join().unwrap();
    }
}

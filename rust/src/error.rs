//! Crate-wide error type.

/// Unified error type for the fedscalar crate.
#[derive(thiserror::Error, Debug)]
pub enum Error {
    /// Errors surfaced by the PJRT runtime (`xla` crate).
    #[error("xla runtime error: {0}")]
    Xla(#[from] xla::Error),

    /// Filesystem / IO failures (artifact loading, CSV output, ...).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// A required AOT artifact is missing or inconsistent with the config.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Malformed configuration or CLI input.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed data file (dataset CSV, manifest, ...).
    #[error("parse error in {path}:{line}: {msg}")]
    Parse {
        path: String,
        line: usize,
        msg: String,
    },

    /// Shape / dimension mismatch between components.
    #[error("shape error: {0}")]
    Shape(String),

    /// An invariant the coordinator relies on was violated at runtime.
    #[error("invariant violated: {0}")]
    Invariant(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn invariant(msg: impl Into<String>) -> Self {
        Error::Invariant(msg.into())
    }
}

//! Crate-wide error type (hand-rolled — `thiserror` is unavailable in the
//! offline build).

use std::fmt;

/// Unified error type for the fedscalar crate.
#[derive(Debug)]
pub enum Error {
    /// Errors surfaced by the PJRT runtime (`xla` crate, `xla` feature).
    Xla(String),

    /// Filesystem / IO failures (artifact loading, CSV output, ...).
    Io(std::io::Error),

    /// A required AOT artifact is missing or inconsistent with the config.
    Artifact(String),

    /// Malformed configuration or CLI input.
    Config(String),

    /// Malformed data file (dataset CSV, manifest, ...).
    Parse {
        path: String,
        line: usize,
        msg: String,
    },

    /// Shape / dimension mismatch between components.
    Shape(String),

    /// An invariant the coordinator relies on was violated at runtime.
    Invariant(String),

    /// A distributed worker stopped responding (thread dead, channel
    /// hung up, or a receive timed out) outside any injected-fault plan.
    WorkerLost { client: usize, round: usize },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla runtime error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Parse { path, line, msg } => {
                write!(f, "parse error in {path}:{line}: {msg}")
            }
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Invariant(msg) => write!(f, "invariant violated: {msg}"),
            Error::WorkerLost { client, round } => {
                write!(f, "worker {client} lost in round {round}")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// A [`Error::Config`] with the given message.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// A [`Error::Artifact`] with the given message.
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    /// A [`Error::Shape`] with the given message.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// An [`Error::Invariant`] with the given message.
    pub fn invariant(msg: impl Into<String>) -> Self {
        Error::Invariant(msg.into())
    }
    /// An [`Error::WorkerLost`] for the given worker and round.
    pub fn worker_lost(client: usize, round: usize) -> Self {
        Error::WorkerLost { client, round }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_variants() {
        assert_eq!(Error::config("bad").to_string(), "config error: bad");
        assert_eq!(Error::shape("s").to_string(), "shape error: s");
        assert_eq!(
            Error::invariant("inv").to_string(),
            "invariant violated: inv"
        );
        assert_eq!(
            Error::worker_lost(3, 12).to_string(),
            "worker 3 lost in round 12"
        );
        assert_eq!(
            Error::Parse {
                path: "f.csv".into(),
                line: 3,
                msg: "bad float".into()
            }
            .to_string(),
            "parse error in f.csv:3: bad float"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}

//! Mini property-testing kit (proptest substitute): seeded generators +
//! a `forall` runner that reports the failing case and its seed so it can
//! be replayed deterministically.
//!
//! Used by the crate's property tests on routing/partition/quantizer/
//! projection invariants.

// Doc debt: this subsystem predates the crate-level `missing_docs`
// warning (added with the daemon PR, which held coordinator/, runlog/,
// telemetry/, and daemon/ to it). Public items below still need doc
// comments; remove this allow once they have them.
#![allow(missing_docs)]

use crate::rng::{GaussianSource, Xoshiro256};

/// Per-case generation context.
pub struct Gen<'a> {
    pub rng: &'a mut Xoshiro256,
    gauss: GaussianSource,
}

impl<'a> Gen<'a> {
    pub fn new(rng: &'a mut Xoshiro256) -> Self {
        Gen {
            rng,
            gauss: GaussianSource::new(),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.gauss.next(self.rng) * scale).collect()
    }

    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    pub fn labels(&mut self, n: usize, classes: usize) -> Vec<i32> {
        (0..n).map(|_| self.rng.below(classes) as i32).collect()
    }

    pub fn pick<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of `prop`. On failure, panics with the case
/// index and the master seed (set `FEDSCALAR_PROP_SEED` to replay).
pub fn forall<F: FnMut(&mut Gen<'_>) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    let seed = std::env::var("FEDSCALAR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xfeed_5ca1);
    let master = Xoshiro256::seed_from(seed);
    for case in 0..cases {
        let mut case_rng = master.child(case as u64);
        let mut g = Gen::new(&mut case_rng);
        if let Err(msg) = prop(&mut g) {
            panic!("property {name:?} failed at case {case}/{cases} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("usize_in bounds", 200, |g| {
            let x = g.usize_in(3, 10);
            if (3..10).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn forall_reports_failure() {
        forall("always fails eventually", 10, |g| {
            if g.usize_in(0, 100) < 1000 {
                // fail on case 3 deterministically
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Xoshiro256::seed_from(1);
        let mut g = Gen::new(&mut rng);
        assert_eq!(g.normal_vec(10, 2.0).len(), 10);
        assert_eq!(g.labels(5, 10).len(), 5);
        assert!(g.labels(100, 3).iter().all(|&l| (0..3).contains(&l)));
        let v = g.uniform_vec(50, -1.0, 1.0);
        assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
        let choices = [1, 2, 3];
        assert!(choices.contains(g.pick(&choices)));
    }
}

//! The MLP forward/backward twin of `python/compile/model.py`.
//!
//! All buffers live in [`MlpScratch`] so the client-stage hot loop never
//! allocates. Backward is hand-derived (the same closed form as the JAX
//! custom_vjp): standard dense backprop through two ReLU layers and a
//! softmax-CE head.

use super::ModelSpec;
use crate::tensor;

/// Stateless MLP; parameters are always passed in as a flat slice.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub spec: ModelSpec,
    offsets: [usize; 7],
}

/// Reusable forward/backward workspace for batches up to `max_batch`.
#[derive(Debug, Clone)]
pub struct MlpScratch {
    max_batch: usize,
    h1: Vec<f32>,
    h2: Vec<f32>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    g2: Vec<f32>, // dL/dh2
    g1: Vec<f32>, // dL/dh1
}

impl MlpScratch {
    pub fn new(spec: &ModelSpec, max_batch: usize) -> Self {
        MlpScratch {
            max_batch,
            h1: vec![0.0; max_batch * spec.hidden1],
            h2: vec![0.0; max_batch * spec.hidden2],
            logits: vec![0.0; max_batch * spec.num_classes],
            probs: vec![0.0; max_batch * spec.num_classes],
            g2: vec![0.0; max_batch * spec.hidden2],
            g1: vec![0.0; max_batch * spec.hidden1],
        }
    }
}

impl Mlp {
    pub fn new(spec: ModelSpec) -> Self {
        let offsets = spec.offsets();
        Mlp { spec, offsets }
    }

    pub fn param_dim(&self) -> usize {
        self.spec.param_dim()
    }

    fn split<'a>(&self, params: &'a [f32]) -> [&'a [f32]; 6] {
        let o = &self.offsets;
        [
            &params[o[0]..o[1]], // w1
            &params[o[1]..o[2]], // b1
            &params[o[2]..o[3]], // w2
            &params[o[3]..o[4]], // b2
            &params[o[4]..o[5]], // w3
            &params[o[5]..o[6]], // b3
        ]
    }

    /// Forward pass: fills scratch.{h1,h2,logits}. `x` is [batch, input_dim].
    pub fn forward(&self, params: &[f32], x: &[f32], batch: usize, s: &mut MlpScratch) {
        assert!(batch <= s.max_batch, "batch {batch} > scratch {}", s.max_batch);
        assert_eq!(params.len(), self.param_dim());
        assert_eq!(x.len(), batch * self.spec.input_dim);
        let [w1, b1, w2, b2, w3, b3] = self.split(params);
        let (i, h1n, h2n, c) = (
            self.spec.input_dim,
            self.spec.hidden1,
            self.spec.hidden2,
            self.spec.num_classes,
        );
        let h1 = &mut s.h1[..batch * h1n];
        tensor::gemm_nn(batch, i, h1n, x, w1, h1);
        tensor::add_bias(batch, h1n, b1, h1);
        tensor::relu_inplace(h1);
        let h2 = &mut s.h2[..batch * h2n];
        tensor::gemm_nn(batch, h1n, h2n, h1, w2, h2);
        tensor::add_bias(batch, h2n, b2, h2);
        tensor::relu_inplace(h2);
        let logits = &mut s.logits[..batch * c];
        tensor::gemm_nn(batch, h2n, c, h2, w3, logits);
        tensor::add_bias(batch, c, b3, logits);
    }

    /// Mean softmax-CE loss of the logits currently in scratch.
    pub fn loss_from_logits(&self, y: &[i32], batch: usize, s: &MlpScratch) -> f32 {
        let c = self.spec.num_classes;
        let mut loss = 0.0f32;
        for r in 0..batch {
            let row = &s.logits[r * c..(r + 1) * c];
            loss += tensor::logsumexp(row) - row[y[r] as usize];
        }
        loss / batch as f32
    }

    /// Forward + loss (no gradient).
    pub fn loss(&self, params: &[f32], x: &[f32], y: &[i32], batch: usize, s: &mut MlpScratch) -> f32 {
        self.forward(params, x, batch, s);
        self.loss_from_logits(y, batch, s)
    }

    /// Forward + backward. Writes dL/dparams into `grad` (overwritten) and
    /// returns the mean loss. Math identical to jax.grad of the L2 model.
    pub fn loss_and_grad(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        batch: usize,
        s: &mut MlpScratch,
        grad: &mut [f32],
    ) -> f32 {
        assert_eq!(grad.len(), self.param_dim());
        self.forward(params, x, batch, s);
        let loss = self.loss_from_logits(y, batch, s);
        let [_, _, w2, _, w3, _] = self.split(params);
        let (i, h1n, h2n, c) = (
            self.spec.input_dim,
            self.spec.hidden1,
            self.spec.hidden2,
            self.spec.num_classes,
        );
        let o = self.offsets;
        grad.fill(0.0);

        // dL/dlogits = (softmax - onehot) / batch
        let probs = &mut s.probs[..batch * c];
        tensor::softmax_rows(batch, c, &s.logits[..batch * c], probs);
        let invb = 1.0 / batch as f32;
        for r in 0..batch {
            probs[r * c + y[r] as usize] -= 1.0;
        }
        tensor::scale(invb, probs);

        {
            // dW3 = h2^T @ probs ; db3 = sum_rows(probs)
            let (gw3, gb3) = {
                let (left, right) = grad.split_at_mut(o[5]);
                (&mut left[o[4]..], &mut right[..c])
            };
            tensor::gemm_tn_acc(batch, h2n, c, &s.h2[..batch * h2n], probs, gw3);
            for r in 0..batch {
                for j in 0..c {
                    gb3[j] += probs[r * c + j];
                }
            }
        }

        // g2 = probs @ w3^T, masked by relu'(h2)
        let g2 = &mut s.g2[..batch * h2n];
        tensor::gemm_nt(batch, c, h2n, probs, w3, g2);
        for (gv, hv) in g2.iter_mut().zip(s.h2[..batch * h2n].iter()) {
            if *hv <= 0.0 {
                *gv = 0.0;
            }
        }

        {
            // dW2 = h1^T @ g2 ; db2 = sum_rows(g2)
            let (left, right) = grad.split_at_mut(o[3]);
            let gw2 = &mut left[o[2]..];
            let gb2 = &mut right[..h2n];
            tensor::gemm_tn_acc(batch, h1n, h2n, &s.h1[..batch * h1n], g2, gw2);
            for r in 0..batch {
                for j in 0..h2n {
                    gb2[j] += g2[r * h2n + j];
                }
            }
        }

        // g1 = g2 @ w2^T, masked by relu'(h1)
        let g1 = &mut s.g1[..batch * h1n];
        tensor::gemm_nt(batch, h2n, h1n, g2, w2, g1);
        for (gv, hv) in g1.iter_mut().zip(s.h1[..batch * h1n].iter()) {
            if *hv <= 0.0 {
                *gv = 0.0;
            }
        }

        {
            // dW1 = x^T @ g1 ; db1 = sum_rows(g1)
            let (left, right) = grad.split_at_mut(o[1]);
            let gw1 = &mut left[o[0]..];
            let gb1 = &mut right[..h1n];
            tensor::gemm_tn_acc(batch, i, h1n, x, g1, gw1);
            for r in 0..batch {
                for j in 0..h1n {
                    gb1[j] += g1[r * h1n + j];
                }
            }
        }

        loss
    }

    /// Accuracy of argmax predictions on a (possibly large) eval set;
    /// processes in chunks of the scratch's max batch.
    pub fn evaluate(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        s: &mut MlpScratch,
    ) -> (f32, f32) {
        let n = y.len();
        assert_eq!(x.len(), n * self.spec.input_dim);
        let c = self.spec.num_classes;
        let chunk = s.max_batch;
        let mut correct = 0usize;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        while done < n {
            let b = chunk.min(n - done);
            let xs = &x[done * self.spec.input_dim..(done + b) * self.spec.input_dim];
            let ys = &y[done..done + b];
            self.forward(params, xs, b, s);
            for r in 0..b {
                let row = &s.logits[r * c..(r + 1) * c];
                loss_sum += (tensor::logsumexp(row) - row[ys[r] as usize]) as f64;
                if tensor::argmax(row) == ys[r] as usize {
                    correct += 1;
                }
            }
            done += b;
        }
        ((loss_sum / n as f64) as f32, correct as f32 / n as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::glorot_init;
    use crate::rng::Xoshiro256;

    fn setup(batch: usize) -> (Mlp, Vec<f32>, Vec<f32>, Vec<i32>, MlpScratch) {
        let spec = ModelSpec::default();
        let mlp = Mlp::new(spec.clone());
        let params = glorot_init(&spec, 0);
        let mut rng = Xoshiro256::seed_from(1);
        let x: Vec<f32> = (0..batch * spec.input_dim)
            .map(|_| rng.uniform_f32())
            .collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.below(10) as i32).collect();
        let scratch = MlpScratch::new(&spec, batch);
        (mlp, params, x, y, scratch)
    }

    #[test]
    fn forward_finite_and_initial_loss_near_ln10() {
        let (mlp, params, x, y, mut s) = setup(32);
        let loss = mlp.loss(&params, &x, &y, 32, &mut s);
        assert!(loss.is_finite());
        // glorot init + uniform labels: loss ~ ln(10) = 2.303
        assert!((loss - (10.0f32).ln()).abs() < 0.5, "loss={loss}");
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (mlp, params, x, y, mut s) = setup(8);
        let mut grad = vec![0.0; mlp.param_dim()];
        mlp.loss_and_grad(&params, &x, &y, 8, &mut s, &mut grad);
        let mut rng = Xoshiro256::seed_from(9);
        let eps = 1e-3f32;
        // check a few coordinates from each parameter block
        let o = mlp.spec.offsets();
        let mut idxs: Vec<usize> = (0..6).map(|b| o[b] + rng.below(o[b + 1] - o[b])).collect();
        idxs.extend((0..6).map(|_| rng.below(mlp.param_dim())));
        for idx in idxs {
            let mut p = params.clone();
            p[idx] += eps;
            let hi = mlp.loss(&p, &x, &y, 8, &mut s);
            p[idx] -= 2.0 * eps;
            let lo = mlp.loss(&p, &x, &y, 8, &mut s);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 5e-3,
                "idx={idx} fd={fd} grad={}",
                grad[idx]
            );
        }
    }

    #[test]
    fn sgd_reduces_loss() {
        // memorize one fixed batch of 32 random-label samples: 1990 params
        // are ample capacity, so full-batch SGD must cut the loss deeply
        let (mlp, mut params, x, y, mut s) = setup(32);
        let mut grad = vec![0.0; mlp.param_dim()];
        let first = mlp.loss_and_grad(&params, &x, &y, 32, &mut s, &mut grad);
        for _ in 0..400 {
            let _ = mlp.loss_and_grad(&params, &x, &y, 32, &mut s, &mut grad);
            tensor::axpy(-0.2, &grad, &mut params);
        }
        let last = mlp.loss(&params, &x, &y, 32, &mut s);
        assert!(last < first * 0.5, "first={first} last={last}");
    }

    #[test]
    fn evaluate_chunks_match_single_shot() {
        let (mlp, params, x, y, _) = setup(64);
        let mut small = MlpScratch::new(&mlp.spec, 10); // forces chunking
        let mut big = MlpScratch::new(&mlp.spec, 64);
        let (l1, a1) = mlp.evaluate(&params, &x, &y, &mut small);
        let (l2, a2) = mlp.evaluate(&params, &x, &y, &mut big);
        assert!((l1 - l2).abs() < 1e-5);
        assert_eq!(a1, a2);
    }

    #[test]
    fn batch_one_works() {
        let (mlp, params, x, y, _) = setup(1);
        let mut s = MlpScratch::new(&mlp.spec, 1);
        let mut grad = vec![0.0; mlp.param_dim()];
        let loss = mlp.loss_and_grad(&params, &x, &y, 1, &mut s, &mut grad);
        assert!(loss.is_finite());
        assert!(grad.iter().any(|&g| g != 0.0));
    }

    #[test]
    #[should_panic(expected = "batch")]
    fn oversized_batch_panics() {
        let (mlp, params, x, _, mut s) = setup(4);
        mlp.forward(&params, &x, 8, &mut s);
    }
}

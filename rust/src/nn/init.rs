//! Glorot-uniform initialization matching `model.init_params` in spirit
//! (same limit `sqrt(6/(fan_in+fan_out))`, zero biases; RNG streams differ —
//! params always cross the backend boundary explicitly so this never
//! matters for cross-backend comparison).

use super::ModelSpec;
use crate::rng::Xoshiro256;

/// Flat glorot-initialized parameter vector for `spec`, deterministic in
/// `seed`.
pub fn glorot_init(spec: &ModelSpec, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0xfed5_ca1a_0000_0001);
    let mut params = vec![0.0f32; spec.param_dim()];
    let o = spec.offsets();
    let dims = [
        (spec.input_dim, spec.hidden1),
        (spec.hidden1, spec.hidden2),
        (spec.hidden2, spec.num_classes),
    ];
    for (layer, &(fan_in, fan_out)) in dims.iter().enumerate() {
        let limit = (6.0f32 / (fan_in + fan_out) as f32).sqrt();
        let w = &mut params[o[layer * 2]..o[layer * 2 + 1]];
        for x in w.iter_mut() {
            *x = rng.uniform_in(-limit, limit);
        }
        // biases (o[2i+1]..o[2i+2]) stay zero
    }
    params
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let spec = ModelSpec::default();
        let a = glorot_init(&spec, 0);
        let b = glorot_init(&spec, 0);
        let c = glorot_init(&spec, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 1990);
    }

    #[test]
    fn weights_bounded_biases_zero() {
        let spec = ModelSpec::default();
        let p = glorot_init(&spec, 2);
        let o = spec.offsets();
        let lim1 = (6.0f32 / (64 + 24) as f32).sqrt();
        assert!(p[o[0]..o[1]].iter().all(|x| x.abs() <= lim1));
        assert!(p[o[1]..o[2]].iter().all(|&x| x == 0.0)); // b1
        assert!(p[o[3]..o[4]].iter().all(|&x| x == 0.0)); // b2
        assert!(p[o[5]..o[6]].iter().all(|&x| x == 0.0)); // b3
        // not all zero overall
        assert!(p.iter().any(|&x| x != 0.0));
    }
}

//! Pure-Rust neural-network substrate: the exact twin of the JAX model in
//! `python/compile/model.py`.
//!
//! Same architecture (64 → 24 ReLU → 12 ReLU → 10, softmax-CE), same flat
//! parameter layout (w1 b1 w2 b2 w3 b3 row-major, d = 1990), same math —
//! the integration suite asserts the two backends produce matching local-SGD
//! deltas given identical parameters and batches.

// Doc debt: this subsystem predates the crate-level `missing_docs`
// warning (added with the daemon PR, which held coordinator/, runlog/,
// telemetry/, and daemon/ to it). Public items below still need doc
// comments; remove this allow once they have them.
#![allow(missing_docs)]

mod init;
mod mlp;

pub use init::glorot_init;
pub use mlp::{Mlp, MlpScratch};

/// Model architecture description (shared by both backends and the config
/// system). The default mirrors the paper's section III experiment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub input_dim: usize,
    pub hidden1: usize,
    pub hidden2: usize,
    pub num_classes: usize,
}

impl Default for ModelSpec {
    fn default() -> Self {
        ModelSpec {
            input_dim: 64,
            hidden1: 24,
            hidden2: 12,
            num_classes: 10,
        }
    }
}

impl ModelSpec {
    /// Total trainable parameter count `d` (1990 for the paper's model —
    /// "approximately 2000").
    pub fn param_dim(&self) -> usize {
        self.input_dim * self.hidden1
            + self.hidden1
            + self.hidden1 * self.hidden2
            + self.hidden2
            + self.hidden2 * self.num_classes
            + self.num_classes
    }

    /// Offsets of (w1, b1, w2, b2, w3, b3) in the flat vector.
    pub fn offsets(&self) -> [usize; 7] {
        let mut o = [0usize; 7];
        let sizes = [
            self.input_dim * self.hidden1,
            self.hidden1,
            self.hidden1 * self.hidden2,
            self.hidden2,
            self.hidden2 * self.num_classes,
            self.num_classes,
        ];
        for i in 0..6 {
            o[i + 1] = o[i] + sizes[i];
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_model_is_1990_params() {
        assert_eq!(ModelSpec::default().param_dim(), 1990);
    }

    #[test]
    fn offsets_partition_the_vector() {
        let spec = ModelSpec::default();
        let o = spec.offsets();
        assert_eq!(o[0], 0);
        assert_eq!(o[6], spec.param_dim());
        assert!(o.windows(2).all(|w| w[0] < w[1]));
    }
}

//! General-purpose substrates that would normally come from crates.io
//! (clap / serde+toml / criterion / env_logger) — unavailable in this
//! offline environment, so implemented and tested here.

// Doc debt: this subsystem predates the crate-level `missing_docs`
// warning (added with the daemon PR, which held coordinator/, runlog/,
// telemetry/, and daemon/ to it). Public items below still need doc
// comments; remove this allow once they have them.
#![allow(missing_docs)]

pub mod bench;
pub mod cli;
pub mod csv;
pub mod logger;
pub mod stats;
pub mod toml_lite;

//! General-purpose substrates that would normally come from crates.io
//! (clap / serde+toml / criterion / env_logger) — unavailable in this
//! offline environment, so implemented and tested here.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod logger;
pub mod stats;
pub mod toml_lite;

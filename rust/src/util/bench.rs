//! Mini-criterion: warmup + timed iterations + mean/σ/min reporting.
//!
//! Used by every `rust/benches/*.rs` target (criterion is unavailable
//! offline). `cargo bench` runs these with `harness = false`.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} / iter (σ {:>10}, min {:>10}, n={})",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.std_dev),
            fmt_duration(self.min),
            self.iters
        )
    }

    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_secs_f64() * 1e9;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark harness: targets a wall-clock budget per case and auto-scales
/// iteration count.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            min_iters: 5,
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            min_iters: 3,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Default budgets, or [`Bench::quick`] when [`quick_requested`]
    /// (how `verify.sh` keeps the tier-1 bench pass under a second).
    pub fn from_env() -> Self {
        if quick_requested() {
            Bench::quick()
        } else {
            Bench::default()
        }
    }

    /// Time `f` (its return value is black-boxed) and print the report line.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + estimate per-iter cost
        let wstart = Instant::now();
        let mut wcount = 0usize;
        while wstart.elapsed() < self.warmup || wcount == 0 {
            black_box(f());
            wcount += 1;
            if wcount >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / wcount as f64;
        let iters = ((self.measure.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = if samples.len() > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (samples.len() - 1) as f64
        } else {
            0.0
        };
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(mean),
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(min),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Standard header for bench binaries.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// The single reader of `FEDSCALAR_BENCH_QUICK`: bench binaries must key
/// BOTH their budgets and their output filename off this, so quick-mode
/// numbers never land in the full-budget trajectory file.
pub fn quick_requested() -> bool {
    std::env::var("FEDSCALAR_BENCH_QUICK").map_or(false, |v| v == "1")
}

/// Write results as machine-readable JSON: a flat `{"name": ns_per_iter}`
/// object (mean ns/iter, 1 decimal). This is the cross-PR perf trajectory
/// format — `benches/hotpath.rs` writes `BENCH_hotpath.json` so successive
/// PRs can diff hot-path timings without scraping stdout.
pub fn write_json<'a>(
    path: impl AsRef<std::path::Path>,
    results: impl IntoIterator<Item = &'a BenchResult>,
) -> std::io::Result<()> {
    let mut body = String::from("{\n");
    let mut first = true;
    for r in results {
        if !first {
            body.push_str(",\n");
        }
        first = false;
        body.push_str(&format!(
            "  \"{}\": {:.1}",
            json_escape(&r.name),
            r.mean_ns()
        ));
    }
    body.push_str("\n}\n");
    std::fs::write(path, body)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::quick();
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns() > 0.0);
        assert!(r.iters >= 3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn write_json_is_flat_name_to_ns() {
        let mut b = Bench::quick();
        b.run("alpha \"quoted\"", || 1 + 1);
        b.run("beta", || 2 + 2);
        let path = std::env::temp_dir().join("fedscalar_bench_test.json");
        write_json(&path, b.results()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(text.starts_with('{') && text.trim_end().ends_with('}'), "{text}");
        assert!(text.contains("\"alpha \\\"quoted\\\"\":"), "{text}");
        assert!(text.contains("\"beta\":"), "{text}");
        // exactly one comma between the two entries
        assert_eq!(text.matches(',').count(), 1, "{text}");
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }
}

//! Mini-criterion: warmup + timed iterations + mean/σ/min reporting.
//!
//! Used by every `rust/benches/*.rs` target (criterion is unavailable
//! offline). `cargo bench` runs these with `harness = false`.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} / iter (σ {:>10}, min {:>10}, n={})",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.std_dev),
            fmt_duration(self.min),
            self.iters
        )
    }

    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_secs_f64() * 1e9;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark harness: targets a wall-clock budget per case and auto-scales
/// iteration count.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            min_iters: 5,
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            min_iters: 3,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Time `f` (its return value is black-boxed) and print the report line.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + estimate per-iter cost
        let wstart = Instant::now();
        let mut wcount = 0usize;
        while wstart.elapsed() < self.warmup || wcount == 0 {
            black_box(f());
            wcount += 1;
            if wcount >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / wcount as f64;
        let iters = ((self.measure.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = if samples.len() > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (samples.len() - 1) as f64
        } else {
            0.0
        };
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(mean),
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(min),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Standard header for bench binaries.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::quick();
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns() > 0.0);
        assert!(r.iters >= 3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }
}

//! Tiny CSV writer for experiment outputs (figures consume these files).

use crate::error::Result;
use std::io::Write;
use std::path::Path;

/// Column-ordered CSV writer.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent directories) and write the header.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter {
            file,
            cols: header.len(),
        })
    }

    /// Write one numeric row (must match the header width).
    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "row width != header width");
        let mut line = String::with_capacity(values.len() * 12);
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format_num(*v));
        }
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    /// Write one row of raw string fields.
    pub fn row_str(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.cols);
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Compact float formatting (no trailing zeros beyond precision needs).
pub fn format_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let path = std::env::temp_dir().join(format!("fedscalar_csv_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[3.0, 0.000012345]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert!(lines[1].starts_with("1,"));
        assert_eq!(lines.len(), 3);
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let path = std::env::temp_dir().join(format!("fedscalar_csv2_{}.csv", std::process::id()));
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }

    #[test]
    fn format_compact() {
        assert_eq!(format_num(3.0), "3");
        assert_eq!(format_num(-15.0), "-15");
        assert!(format_num(0.5).contains('e'));
    }
}

//! Summary statistics + series helpers used by the experiment harness
//! (multi-run averaging, accuracy-at-budget interpolation).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 if n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on the sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Element-wise mean of equal-length series (e.g. loss curves across runs).
pub fn mean_series(series: &[Vec<f64>]) -> Vec<f64> {
    if series.is_empty() {
        return Vec::new();
    }
    let n = series[0].len();
    assert!(series.iter().all(|s| s.len() == n), "ragged series");
    let mut out = vec![0.0; n];
    for s in series {
        for (o, x) in out.iter_mut().zip(s) {
            *o += x;
        }
    }
    let inv = 1.0 / series.len() as f64;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Value of a monotone-x step series at query `q`: the last `y` whose `x <= q`
/// (None if q precedes the first point). Used for "accuracy at budget B"
/// readouts on the Fig 4-6 curves.
pub fn value_at(xs: &[f64], ys: &[f64], q: f64) -> Option<f64> {
    assert_eq!(xs.len(), ys.len());
    let mut ans = None;
    for (x, y) in xs.iter().zip(ys) {
        if *x <= q {
            ans = Some(*y);
        } else {
            break;
        }
    }
    ans
}

/// First `x` at which `y` reaches `target` (None if never). Used for
/// "time/bits/energy to accuracy" readouts.
pub fn first_crossing(xs: &[f64], ys: &[f64], target: f64) -> Option<f64> {
    assert_eq!(xs.len(), ys.len());
    xs.iter()
        .zip(ys)
        .find(|(_, y)| **y >= target)
        .map(|(x, _)| *x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn series_mean() {
        let m = mean_series(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m, vec![2.0, 3.0]);
        assert!(mean_series(&[]).is_empty());
    }

    #[test]
    fn value_at_and_crossing() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.1, 0.4, 0.6, 0.9];
        assert_eq!(value_at(&xs, &ys, 1.5), Some(0.4));
        assert_eq!(value_at(&xs, &ys, -1.0), None);
        assert_eq!(value_at(&xs, &ys, 99.0), Some(0.9));
        assert_eq!(first_crossing(&xs, &ys, 0.5), Some(2.0));
        assert_eq!(first_crossing(&xs, &ys, 0.95), None);
    }
}

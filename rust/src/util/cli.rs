//! Tiny declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, defaults,
//! and generated `--help` text. Used by `main.rs` and every example binary.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct ArgSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set + parsed values.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<ArgSpec>,
    values: BTreeMap<&'static str, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &'static str) -> Self {
        Args {
            program: program.to_string(),
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.program, self.about);
        for spec in &self.specs {
            let tail = if spec.is_flag {
                String::new()
            } else if let Some(d) = &spec.default {
                format!(" <value>  (default: {d})")
            } else {
                " <value>  (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, tail, spec.help));
        }
        s
    }

    /// Parse a token stream (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self> {
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(Error::config(self.usage()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| Error::config(format!("unknown option --{key}\n\n{}", self.usage())))?
                    .clone();
                let value = if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(Error::config(format!("--{key} is a flag, no value allowed")));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| Error::config(format!("--{key} requires a value")))?
                };
                self.values.insert(spec.name, value);
            } else {
                self.positionals.push(tok);
            }
        }
        // required check
        for spec in &self.specs {
            if !spec.is_flag && spec.default.is_none() && !self.values.contains_key(spec.name) {
                return Err(Error::config(format!("missing required --{}", spec.name)));
            }
        }
        Ok(self)
    }

    /// Was `--name` explicitly passed (vs falling back to its default)?
    pub fn provided(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_else(|| panic!("undeclared option {name}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("prog", "test program")
            .opt("rounds", "100", "number of rounds")
            .opt("method", "fedscalar", "strategy")
            .flag("verbose", "talk more")
            .required("out", "output path")
    }

    #[test]
    fn parse_defaults_and_overrides() {
        let a = spec().parse(argv("--out /tmp/x --rounds 5 --verbose")).unwrap();
        assert_eq!(a.get("rounds"), "5");
        assert_eq!(a.get("method"), "fedscalar");
        assert_eq!(a.get("out"), "/tmp/x");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_usize("rounds").unwrap(), 5);
        // provided() distinguishes explicit flags from defaults (what
        // lets a --config file keep its values unless overridden)
        assert!(a.provided("rounds"));
        assert!(!a.provided("method"));
    }

    #[test]
    fn parse_equals_form() {
        let a = spec().parse(argv("--out=/y --rounds=7")).unwrap();
        assert_eq!(a.get("out"), "/y");
        assert_eq!(a.get_usize("rounds").unwrap(), 7);
    }

    #[test]
    fn missing_required_rejected() {
        assert!(spec().parse(argv("--rounds 5")).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(argv("--out x --bogus 1")).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec().parse(argv("--out x --verbose=yes")).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = spec().parse(argv("train --out x extra")).unwrap();
        assert_eq!(a.positionals(), &["train".to_string(), "extra".to_string()]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = spec().parse(argv("--help")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--rounds"));
        assert!(msg.contains("required"));
    }

    #[test]
    fn bad_number_reported() {
        let a = spec().parse(argv("--out x --rounds nope")).unwrap();
        assert!(a.get_usize("rounds").is_err());
    }
}

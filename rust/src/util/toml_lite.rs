//! Minimal TOML-subset parser (serde+toml substitute) for experiment
//! configs: `[section]` headers, `key = value` with string / bool / int /
//! float values, `#` comments. No arrays-of-tables, no nesting beyond one
//! level — exactly what the config system needs.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// `section -> key -> value`; top-level keys live under the "" section.
#[derive(Debug, Clone, Default)]
pub struct Document {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Document> {
        let mut doc = Document::default();
        let mut current = String::new();
        doc.sections.entry(current.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let name = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| parse_err(lineno, "unterminated [section]"))?
                    .trim()
                    .to_string();
                if name.is_empty() {
                    return Err(parse_err(lineno, "empty section name"));
                }
                current = name;
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| parse_err(lineno, "expected key = value"))?;
            let key = k.trim().to_string();
            if key.is_empty() {
                return Err(parse_err(lineno, "empty key"));
            }
            let value = parse_value(v.trim()).ok_or_else(|| {
                parse_err(lineno, &format!("cannot parse value {:?}", v.trim()))
            })?;
            doc.sections
                .get_mut(&current)
                .expect("section exists")
                .insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a Value) -> &'a Value {
        self.get(section, key).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped.strip_suffix('"')?;
        if inner.contains('"') {
            return None;
        }
        return Some(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

fn parse_err(lineno: usize, msg: &str) -> Error {
    Error::Parse {
        path: "<toml>".into(),
        line: lineno + 1,
        msg: msg.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
rounds = 1500
alpha = 0.003          # stepsize
method = "fedscalar"   # strategy
verbose = true

[network]
bandwidth_bps = 100000
sigma = 0.25
tdma = false
"#;

    #[test]
    fn parse_sample() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("", "rounds").unwrap().as_int(), Some(1500));
        assert_eq!(doc.get("", "alpha").unwrap().as_float(), Some(0.003));
        assert_eq!(doc.get("", "method").unwrap().as_str(), Some("fedscalar"));
        assert_eq!(doc.get("", "verbose").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("network", "bandwidth_bps").unwrap().as_int(),
            Some(100000)
        );
        assert_eq!(doc.get("network", "sigma").unwrap().as_float(), Some(0.25));
        assert_eq!(doc.get("network", "tdma").unwrap().as_bool(), Some(false));
        assert!(doc.get("network", "missing").is_none());
        assert!(doc.get("nosection", "x").is_none());
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Document::parse("x = 3\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Document::parse("s = \"a#b\" # comment\n").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        for (bad, line) in [
            ("[unterminated\n", 1),
            ("keyonly\n", 1),
            ("x = \n", 1),
            ("\n= 3\n", 2),
            ("ok = 1\nx = @@@\n", 2),
        ] {
            match Document::parse(bad) {
                Err(Error::Parse { line: l, .. }) => assert_eq!(l, line, "{bad:?}"),
                other => panic!("expected parse error for {bad:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn get_or_default() {
        let doc = Document::parse("x = 1\n").unwrap();
        let d = Value::Int(9);
        assert_eq!(doc.get_or("", "x", &d).as_int(), Some(1));
        assert_eq!(doc.get_or("", "y", &d).as_int(), Some(9));
    }
}

//! Minimal leveled logger (env_logger substitute). Level comes from
//! `FEDSCALAR_LOG` (error|warn|info|debug|trace), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn init_from_env() {
    let lvl = std::env::var("FEDSCALAR_LOG")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(Level::Info);
    set_level(lvl);
    START.get_or_init(Instant::now);
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
    START.get_or_init(Instant::now);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    crate::telemetry::log_message(l as usize);
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Serializes tests that mutate the global LEVEL.
    static LEVEL_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        let _guard = LEVEL_LOCK.lock().unwrap();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn trace_macro_emits_and_is_counted() {
        let _guard = LEVEL_LOCK.lock().unwrap();
        crate::telemetry::force(Some(true));
        set_level(Level::Trace);
        let counter = &crate::telemetry::global().log_messages[Level::Trace as usize];
        let before = counter.get();
        crate::log_trace!("trace is wired through: {}", 42);
        assert!(counter.get() >= before + 1, "emitted trace not counted");
        // below the filter: not emitted, not counted
        set_level(Level::Info);
        let muted = counter.get();
        crate::log_trace!("filtered out");
        assert_eq!(counter.get(), muted);
        crate::telemetry::force(None);
    }
}

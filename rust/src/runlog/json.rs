//! Minimal JSON reader/writer for the run journal — zero dependencies.
//!
//! The journal needs exactly one property from its encoding: **bit-exact
//! float round-trips**. Rust's `f64` `Display` prints the shortest string
//! that parses back to the same bits, and `str::parse::<f64>` is correctly
//! rounded, so `Num(v)` survives write→parse unchanged for every finite
//! `v`. Non-finite values are written as `null` (JSON has no NaN) and read
//! back as NaN via [`Json::as_f64`]; the only non-finite float the journal
//! carries is an unevaluated `train_loss`, where NaN is the sentinel and
//! the distinction from ±inf is irrelevant.
//!
//! Integers ride in `Num` too — every counter in the journal (rounds,
//! bits, client ids) is far below 2^53, where `f64` is exact.

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A parsed JSON value. Objects keep insertion order (`Vec`, linear
/// lookup) — journal objects have a handful of keys.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null` — also the writer's spelling of a non-finite float.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (integers ride exactly below 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value; `Null` reads as NaN (the writer's spelling of a
    /// non-finite float).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// String value; `None` for every other variant.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array items; `None` for every other variant.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Serialize to a single line (no pretty-printing, no trailing
    /// newline).
    pub fn to_json_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    // f64 Display: shortest round-trip, never exponent form.
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one JSON value; trailing non-whitespace is an error.
pub fn parse(s: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: s.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(bad("trailing bytes after JSON value"));
    }
    Ok(v)
}

fn bad(msg: &str) -> Error {
    Error::invariant(format!("journal JSON: {msg}"))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, byte: u8, what: &str) -> Result<()> {
        if self.peek() == Some(byte) {
            self.pos += 1;
            Ok(())
        } else {
            Err(bad(what))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(bad("unrecognized literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(bad("truncated or malformed value")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{', "expected '{'")?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "expected ':' after object key")?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(bad("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[', "expected '['")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(bad("expected ',' or ']' in array")),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| bad("invalid utf-8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| bad("malformed number"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"', "expected '\"'")?;
        let mut out = String::new();
        loop {
            // Copy the raw span up to the next delimiter in one push — the
            // input is valid UTF-8 and both delimiters are ASCII, so the
            // span boundaries never split a multibyte sequence.
            let start = self.pos;
            while !matches!(self.peek(), Some(b'"' | b'\\') | None) {
                self.pos += 1;
            }
            if self.pos > start {
                let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| bad("invalid utf-8 in string"))?;
                out.push_str(chunk);
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.escape(&mut out)?;
                }
                _ => return Err(bad("unterminated string")),
            }
        }
    }

    fn escape(&mut self, out: &mut String) -> Result<()> {
        let c = self.peek().ok_or_else(|| bad("truncated escape"))?;
        self.pos += 1;
        match c {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{0008}'),
            b'f' => out.push('\u{000c}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xd800..0xdc00).contains(&hi) {
                    // High surrogate: a \uXXXX low surrogate must follow.
                    self.eat(b'\\', "expected low surrogate")?;
                    self.eat(b'u', "expected low surrogate")?;
                    let lo = self.hex4()?;
                    if !(0xdc00..0xe000).contains(&lo) {
                        return Err(bad("invalid low surrogate"));
                    }
                    0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                } else {
                    hi
                };
                out.push(char::from_u32(code).ok_or_else(|| bad("invalid codepoint"))?);
            }
            _ => return Err(bad("unknown escape")),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos.checked_add(4).filter(|&e| e <= self.bytes.len());
        let end = end.ok_or_else(|| bad("truncated \\u escape"))?;
        let text = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| bad("invalid \\u escape"))?;
        let v = u32::from_str_radix(text, 16).map_err(|_| bad("invalid \\u escape"))?;
        self.pos = end;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(j: &Json) -> Json {
        parse(&j.to_json_string()).expect("round-trip parse")
    }

    #[test]
    fn floats_round_trip_bit_exact() {
        for v in [
            0.0,
            -0.0,
            0.1 + 0.2,
            1.0 / 3.0,
            std::f64::consts::PI,
            1e-300,
            -2.2250738585072014e-308,
            f64::MAX,
            9_007_199_254_740_991.0, // 2^53 - 1
            123456.789e3,
        ] {
            let back = roundtrip(&Json::Num(v));
            match back {
                Json::Num(b) => assert_eq!(b.to_bits(), v.to_bits(), "value {v}"),
                other => panic!("expected Num, got {other:?}"),
            }
        }
    }

    #[test]
    fn non_finite_floats_write_null_and_read_nan() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(v).to_json_string();
            assert_eq!(s, "null");
            let back = parse(&s).unwrap();
            assert!(back.as_f64().unwrap().is_nan());
        }
    }

    #[test]
    fn strings_escape_and_round_trip() {
        for s in [
            "plain",
            "with \"quotes\" and \\backslash\\",
            "newline\nand\ttab\rand\u{0001}ctrl",
            "unicode: żółć 😀 → λ",
            "",
        ] {
            let back = roundtrip(&Json::Str(s.to_string()));
            assert_eq!(back, Json::Str(s.to_string()));
        }
    }

    #[test]
    fn surrogate_pairs_parse() {
        let j = parse(r#""😀""#).unwrap();
        assert_eq!(j, Json::Str("😀".to_string()));
        assert!(parse(r#""\ud83d""#).is_err(), "lone high surrogate");
        assert!(parse(r#""\ud83dA""#).is_err(), "bad low surrogate");
    }

    #[test]
    fn nested_structures_round_trip() {
        let j = Json::Obj(vec![
            ("v".to_string(), Json::Num(1.0)),
            (
                "arr".to_string(),
                Json::Arr(vec![Json::Null, Json::Bool(true), Json::Str("x".into())]),
            ),
            ("empty_obj".to_string(), Json::Obj(vec![])),
            ("empty_arr".to_string(), Json::Arr(vec![])),
        ]);
        assert_eq!(roundtrip(&j), j);
    }

    #[test]
    fn object_lookup_preserves_order_and_finds_keys() {
        let j = parse(r#"{"a": 1, "b": [2, 3], "c": "s"}"#).unwrap();
        assert_eq!(j.get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("b").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(j.get("c").and_then(Json::as_str), Some("s"));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn truncated_and_malformed_inputs_error() {
        for s in [
            "",
            "{",
            "{\"a\":",
            "[1, 2",
            "\"unterminated",
            "{\"a\" 1}",
            "nul",
            "1.2.3",
            "{} trailing",
            "\"bad \\q escape\"",
        ] {
            assert!(parse(s).is_err(), "input {s:?} should fail");
        }
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_json_string(), "42");
        assert_eq!(Json::Num(-7.0).to_json_string(), "-7");
        let big = (1u64 << 53) as f64;
        assert_eq!(Json::Num(big).to_json_string(), "9007199254740992");
    }
}

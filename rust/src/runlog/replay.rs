//! Replay-based crash recovery: `fedscalar resume <log>` rebuilds a run
//! from its journal and continues bit-identically.
//!
//! The journal holds three kinds of state:
//!
//! * the **preamble** (`RunStarted`): engine, backend, run seed, and the
//!   full config TOML — everything needed to reconstruct the engines;
//! * the **round stream** (`RoundPlanned`/`RoundClosed`): who was
//!   selected and who died, which lets [`replay`](self) drive the cheap
//!   leader-side stateful streams (sampler RNG, fading channels, batch
//!   cursors, batteries, the virtual clock, dead-set bookkeeping)
//!   forward without computing a single gradient;
//! * the latest **snapshot**: the expensive state (params, strategy
//!   blobs, cumulative counters, per-worker checkpoints) restored
//!   directly.
//!
//! Replaying `0..snapshot.next_round` then restoring the snapshot leaves
//! every RNG position, cursor, and counter exactly where the original
//! run had them at that boundary, so the continued rounds are
//! bit-identical to an uninterrupted run — the `runlog` integration
//! suite pins this for both engines across strategies.

use crate::config::ExperimentConfig;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::{DistributedEngine, Engine};
use crate::error::{Error, Result};
use crate::exp::figures::{make_backend, BackendKind};
use crate::metrics::RunHistory;
use crate::runlog::{Event, Journal, RoundEntry, RunLog};
use std::path::Path;

/// What a completed resume hands back to the CLI.
pub struct Resumed {
    /// Full metric history: journal-recovered records plus the
    /// freshly-run remainder.
    pub history: RunHistory,
    /// The round the run continued from (0 = full from-scratch replay).
    pub resumed_at: u64,
    /// Engine name from the journal preamble (`sequential`/`distributed`).
    pub engine: String,
    /// Compute backend the resumed rounds ran on.
    pub backend: String,
    /// Federated method name (`fedscalar`/`fedavg`/...).
    pub method: String,
}

/// A resumable engine, replayed and restored but not yet driven: either
/// variant stands exactly where the original run stood at the resume
/// boundary, with the `RunResumed` marker already journaled and the
/// journal re-attached as its sink. Call the engine's `run_from`
/// (or step rounds manually) starting at [`PreparedResume::resumed_at`].
pub enum ResumedEngine {
    /// The in-process engine (journal preamble said `sequential`).
    Sequential(Box<Engine>),
    /// The threaded frame-passing engine (`distributed`).
    Distributed(Box<DistributedEngine>),
}

/// The output of [`prepare_resume`]: an engine re-attached to its
/// journal, plus the preamble facts a caller reports.
pub struct PreparedResume {
    /// The restored engine, ready to run from [`Self::resumed_at`].
    pub engine: ResumedEngine,
    /// First round left to run (0 = full from-scratch replay).
    pub resumed_at: u64,
    /// Engine name from the journal preamble.
    pub engine_name: String,
    /// Compute backend the continued rounds will run on.
    pub backend: String,
    /// Federated method name.
    pub method: String,
    /// Total rounds the run is configured for.
    pub rounds: usize,
    /// The config's evaluation cadence — a caller stepping rounds
    /// manually must reproduce `k % eval_every == 0 || k + 1 == rounds`
    /// to stay bit-identical to an uninterrupted run.
    pub eval_every: usize,
}

/// Resolve a journal's backend name. Accepts everything the CLI does,
/// plus the display name the preamble records (`BackendKind::name`
/// returns `"xla-pjrt"`, which `parse` alone does not accept).
fn parse_backend(name: &str) -> Result<BackendKind> {
    if name == "xla-pjrt" {
        return Ok(BackendKind::Xla);
    }
    BackendKind::parse(name)
        .ok_or_else(|| Error::config(format!("journal names unknown backend {name:?}")))
}

/// The fully-journaled entry for round `k` — a resume needs both the
/// plan and the close for every round below the snapshot.
fn entry(journal: &Journal, k: u64) -> Result<&RoundEntry> {
    let e = journal
        .rounds
        .get(&k)
        .ok_or_else(|| Error::invariant(format!("journal is missing round {k} below its snapshot")))?;
    if e.close.is_none() {
        return Err(Error::invariant(format!(
            "journal round {k} below the snapshot was never closed"
        )));
    }
    Ok(e)
}

/// Rebuild the run journaled at `path` up to (but not past) the resume
/// boundary: replay the leader-side streams to the latest snapshot,
/// restore it, append a `RunResumed` marker, and re-attach the journal
/// as the engine's sink — everything [`resume_run`] does short of
/// driving the remaining rounds. The daemon uses this to re-attach to
/// every unfinished journal at startup and then drive each engine on
/// its own thread; [`Journal::parse_str`]'s fold lets the later
/// timeline win when the continued rounds re-journal into the same
/// file. `backend_override` substitutes the compute backend (sequential
/// engine only — results are bit-identical across backends by the
/// cross-backend equality contract).
pub fn prepare_resume(
    path: impl AsRef<Path>,
    backend_override: Option<&str>,
) -> Result<PreparedResume> {
    let path = path.as_ref();
    let journal = Journal::parse_file(path)?;
    if journal.finished {
        return Err(Error::config(
            "journal records a finished run — nothing to resume",
        ));
    }
    let mut cfg = ExperimentConfig::from_toml_str(&journal.start.config_toml)?;
    cfg.runlog.path = Some(path.to_path_buf());
    let run_seed = journal.start.run_seed;
    let at = journal.resume_round();
    let backend_name = backend_override.unwrap_or(&journal.start.backend);
    let kind = parse_backend(backend_name)?;

    let engine = match journal.start.engine.as_str() {
        "sequential" => {
            let be = make_backend(kind, &cfg)?;
            let mut engine = Engine::from_config(&cfg, be, run_seed)?;
            for k in 0..at {
                let e = entry(&journal, k)?;
                let close = e.close.as_ref().expect("entry() checked close");
                if !close.new_dead.is_empty() {
                    return Err(Error::invariant(format!(
                        "sequential journal marks workers dead in round {k}"
                    )));
                }
                engine.replay_round_streams(k as usize, &e.active)?;
            }
            if at > 0 {
                let snap = journal.snapshot.as_ref().expect("at > 0 implies a snapshot");
                engine.restore(&Checkpoint {
                    run_seed,
                    method: cfg.fed.method.name(),
                    round: at,
                    params: snap.params.clone(),
                    cum_bits: snap.cum_bits,
                    cum_downlink_bits: snap.cum_downlink_bits,
                    cum_sim_seconds: snap.cum_sim_seconds,
                    cum_energy_joules: snap.cum_energy_joules,
                    strategy_state: snap.strategy_state.clone(),
                })?;
            }
            engine.seed_history(journal.records_before(at));
            let mut log = RunLog::append(path)?;
            log.push(&Event::RunResumed { at_round: at })?;
            engine.set_runlog(log);
            ResumedEngine::Sequential(Box::new(engine))
        }
        "distributed" => {
            if matches!(kind, BackendKind::Xla) {
                return Err(Error::config(
                    "a distributed journal resumes with pure-rust workers; drop --backend",
                ));
            }
            let mut engine = if at > 0 {
                let snap = journal.snapshot.as_ref().expect("at > 0 implies a snapshot");
                let workers = snap
                    .workers
                    .iter()
                    .map(|w| (w.strategy_state.clone(), w.rounds_computed))
                    .collect();
                DistributedEngine::from_config_resumed(&cfg, run_seed, workers)?
            } else {
                DistributedEngine::from_config(&cfg, run_seed)?
            };
            for k in 0..at {
                let e = entry(&journal, k)?;
                let close = e.close.as_ref().expect("entry() checked close");
                engine.replay_round_streams(k as usize, &e.active, &close.new_dead)?;
            }
            if at > 0 {
                let snap = journal.snapshot.as_ref().expect("at > 0 implies a snapshot");
                engine.restore_leader(snap)?;
            }
            engine.seed_history(journal.records_before(at));
            let mut log = RunLog::append(path)?;
            log.push(&Event::RunResumed { at_round: at })?;
            engine.set_runlog(log);
            ResumedEngine::Distributed(Box::new(engine))
        }
        other => {
            return Err(Error::config(format!(
                "journal names unknown engine {other:?}"
            )))
        }
    };
    Ok(PreparedResume {
        engine,
        resumed_at: at,
        engine_name: journal.start.engine,
        backend: kind.name().to_string(),
        method: cfg.fed.method.name(),
        rounds: cfg.fed.rounds,
        eval_every: cfg.fed.eval_every,
    })
}

/// Resume the run journaled at `path`: [`prepare_resume`], then drive
/// the remaining rounds to completion — the `fedscalar resume` CLI path.
pub fn resume_run(path: impl AsRef<Path>, backend_override: Option<&str>) -> Result<Resumed> {
    let prepared = prepare_resume(path, backend_override)?;
    let at = prepared.resumed_at;
    let history = match prepared.engine {
        ResumedEngine::Sequential(mut engine) => engine.run_from(at as usize)?,
        ResumedEngine::Distributed(mut engine) => engine.run_from(at as usize)?,
    };
    Ok(Resumed {
        history,
        resumed_at: at,
        engine: prepared.engine_name,
        backend: prepared.backend,
        method: prepared.method,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip_through_the_preamble() {
        assert!(matches!(parse_backend("xla-pjrt"), Ok(BackendKind::Xla)));
        assert!(matches!(parse_backend("xla"), Ok(BackendKind::Xla)));
        assert!(matches!(
            parse_backend("pure-rust"),
            Ok(BackendKind::PureRust)
        ));
        assert!(parse_backend("tpu").is_err());
    }

    #[test]
    fn refuses_a_finished_journal() {
        let cfg = ExperimentConfig::paper_section_iii();
        let path = std::env::temp_dir().join("fedscalar_replay_finished_test.jsonl");
        let mut log =
            crate::runlog::start_run(&path, "sequential", "pure-rust", 1, &cfg).unwrap();
        log.push(&Event::RunFinished { rounds: 0 }).unwrap();
        drop(log);
        let err = resume_run(&path, None).unwrap_err();
        assert!(err.to_string().contains("finished"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}

//! Event-sourced run journal: durable JSONL log, replay-based resume,
//! and offline bottleneck analysis.
//!
//! Both engines write one versioned event per line through a single
//! [`RunLog`] sink (`--log run.jsonl` or `[runlog] path`): `RunStarted`
//! (config + seed preamble), `RoundPlanned` (the selected set),
//! `RoundClosed` (delivery outcomes, phase timings, the eval record),
//! a periodic `Snapshot` (params + strategy blobs + cums, every
//! `snapshot_every` rounds), and `RunFinished`. Every line is flushed as
//! written, so a crash loses at most the line in flight.
//!
//! Recovery leans on the determinism contract — everything in a run is a
//! pure function of `(config, run_seed, round)` — so `fedscalar resume`
//! ([`replay`]) rebuilds the engine from the embedded config, *replays*
//! rounds `0..snapshot.next_round` against the cheap stateful streams
//! (sampler/fading RNG positions, batch cursors, batteries, the clock)
//! without computing any gradients, restores params/strategy state from
//! the last snapshot, and continues **bit-identically** to an
//! uninterrupted run. This subsumes both the v2 checkpoint file
//! (`coordinator::checkpoint`, which resumes statistically-equivalent,
//! not bit-identical) and the fault layer's in-memory `WorkerCheckpoint`
//! path. [`report`] answers "which client/phase gated round k" from the
//! same stream.
//!
//! A truncated final line (the crash case) is tolerated and ignored;
//! malformed *interior* lines are corruption and refuse to parse.

pub mod event;
pub mod json;
pub mod replay;
pub mod report;

pub use event::{Event, RoundClose, RunStarted, SnapshotState, WorkerState, SCHEMA_VERSION};

use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Append-only journal writer — the one sink both engines log through.
pub struct RunLog {
    out: BufWriter<File>,
    path: std::path::PathBuf,
}

impl RunLog {
    /// Create (truncate) a journal at `path`.
    pub fn create(path: impl AsRef<Path>) -> Result<RunLog> {
        Ok(RunLog {
            out: BufWriter::new(File::create(&path)?),
            path: path.as_ref().to_path_buf(),
        })
    }

    /// Open an existing journal for appending (resume).
    pub fn append(path: impl AsRef<Path>) -> Result<RunLog> {
        let f = OpenOptions::new().append(true).open(&path)?;
        Ok(RunLog {
            out: BufWriter::new(f),
            path: path.as_ref().to_path_buf(),
        })
    }

    /// Where the journal lives — the telemetry sidecar is derived from it.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one event line and flush it to the OS — durability is the
    /// whole point of the journal, so every event hits the file before
    /// the round proceeds.
    pub fn push(&mut self, ev: &Event) -> Result<()> {
        let mut line = ev.encode();
        line.push('\n');
        let t0 = crate::telemetry::active().then(std::time::Instant::now);
        self.out.write_all(line.as_bytes())?;
        self.out.flush()?;
        if let Some(t0) = t0 {
            crate::telemetry::runlog_flush(t0.elapsed().as_secs_f64());
        }
        Ok(())
    }
}

/// Create a journal and write its `RunStarted` preamble — the shared
/// entry point for `fedscalar train --log` and the tests.
pub fn start_run(
    path: impl AsRef<Path>,
    engine: &str,
    backend: &str,
    run_seed: u64,
    cfg: &ExperimentConfig,
) -> Result<RunLog> {
    let mut log = RunLog::create(path)?;
    log.push(&Event::RunStarted(RunStarted {
        engine: engine.to_string(),
        backend: backend.to_string(),
        run_seed,
        config_toml: cfg.to_toml_string()?,
    }))?;
    Ok(log)
}

/// One round's worth of journal state after folding plan + close.
#[derive(Debug, Clone)]
pub struct RoundEntry {
    /// The client set `RoundPlanned` selected for the round.
    pub active: Vec<usize>,
    /// `None` for a dangling `RoundPlanned` at a crash tail.
    pub close: Option<RoundClose>,
}

/// A parsed journal: the event stream folded into resumable state.
#[derive(Debug, Clone)]
pub struct Journal {
    /// The `RunStarted` preamble (engine, backend, seed, config TOML).
    pub start: RunStarted,
    /// Every planned round, keyed by round index.
    pub rounds: BTreeMap<u64, RoundEntry>,
    /// The latest usable snapshot, if any survived `RunResumed` pruning.
    pub snapshot: Option<SnapshotState>,
    /// Whether a `RunFinished` closed the (latest) timeline.
    pub finished: bool,
}

impl Journal {
    /// Read and fold the journal at `path` (see [`Journal::parse_str`]).
    pub fn parse_file(path: impl AsRef<Path>) -> Result<Journal> {
        let text = std::fs::read_to_string(&path)?;
        Journal::parse_str(&text)
    }

    /// Fold the event lines. The final line may be truncated mid-write
    /// (crash) — a decode failure there is ignored; anywhere else it is
    /// corruption and errors out.
    pub fn parse_str(text: &str) -> Result<Journal> {
        let lines: Vec<&str> = text.lines().collect();
        let last_content = lines.iter().rposition(|l| !l.trim().is_empty());
        let mut journal: Option<Journal> = None;
        for (i, line) in lines.iter().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let ev = match Event::decode(line) {
                Ok(ev) => ev,
                Err(e) => {
                    if Some(i) == last_content {
                        break; // torn final write — resume discards it
                    }
                    return Err(Error::invariant(format!(
                        "journal line {}: {e}",
                        i + 1
                    )));
                }
            };
            match (&mut journal, ev) {
                (None, Event::RunStarted(s)) => {
                    journal = Some(Journal {
                        start: s,
                        rounds: BTreeMap::new(),
                        snapshot: None,
                        finished: false,
                    });
                }
                (None, _) => {
                    return Err(Error::invariant(
                        "journal does not begin with RunStarted",
                    ));
                }
                (Some(_), Event::RunStarted(_)) => {
                    return Err(Error::invariant("journal contains a second RunStarted"));
                }
                (Some(j), Event::RoundPlanned { round, active }) => {
                    j.rounds.insert(round, RoundEntry { active, close: None });
                }
                (Some(j), Event::RoundClosed(c)) => {
                    let entry = j.rounds.get_mut(&c.round).ok_or_else(|| {
                        Error::invariant(format!("round {} closed without a plan", c.round))
                    })?;
                    entry.close = Some(*c);
                }
                (Some(j), Event::Snapshot(s)) => {
                    j.snapshot = Some(*s);
                }
                (Some(j), Event::RunResumed { at_round }) => {
                    // A resumed run re-writes rounds >= at_round; the later
                    // timeline wins, so drop the superseded suffix.
                    j.rounds.retain(|&r, _| r < at_round);
                    if j.snapshot.as_ref().is_some_and(|s| s.next_round > at_round) {
                        j.snapshot = None;
                    }
                    j.finished = false;
                }
                (Some(j), Event::RunFinished { .. }) => {
                    j.finished = true;
                }
            }
        }
        journal.ok_or_else(|| Error::invariant("journal is empty or has no RunStarted"))
    }

    /// Evaluated records for rounds strictly below `before_round`, in
    /// round order — the history prefix a resume seeds.
    pub fn records_before(&self, before_round: u64) -> Vec<crate::metrics::RoundRecord> {
        self.rounds
            .range(..before_round)
            .filter_map(|(_, e)| e.close.as_ref().and_then(|c| c.record.clone()))
            .collect()
    }

    /// The round replay resumes from: the last snapshot's `next_round`,
    /// or 0 (from-scratch replay) when no snapshot survived.
    pub fn resume_round(&self) -> u64 {
        self.snapshot.as_ref().map_or(0, |s| s.next_round)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn started_line() -> String {
        Event::RunStarted(RunStarted {
            engine: "sequential".into(),
            backend: "pure-rust".into(),
            run_seed: 7,
            config_toml: "[fed]\n".into(),
        })
        .encode()
    }

    fn planned(round: u64, active: &[usize]) -> String {
        Event::RoundPlanned {
            round,
            active: active.to_vec(),
        }
        .encode()
    }

    fn closed(round: u64) -> String {
        Event::RoundClosed(Box::new(RoundClose {
            round,
            outcome: vec![],
            round_seconds: 1.0,
            energy_joules: 0.0,
            uplink_bits: 0,
            downlink_bits: 0,
            bcast_seconds: 0.0,
            phase_start_seconds: 0.0,
            ready_seconds: vec![],
            finish_seconds: vec![],
            new_dead: vec![],
            host_phase_ms: vec![],
            record: None,
        }))
        .encode()
    }

    #[test]
    fn folds_a_clean_journal() {
        let text = [
            started_line(),
            planned(0, &[0, 1]),
            closed(0),
            planned(1, &[1]),
            Event::RunFinished { rounds: 2 }.encode(),
        ]
        .join("\n");
        let j = Journal::parse_str(&text).unwrap();
        assert_eq!(j.start.run_seed, 7);
        assert_eq!(j.rounds.len(), 2);
        assert!(j.rounds[&0].close.is_some());
        assert!(j.rounds[&1].close.is_none(), "dangling plan kept as-is");
        assert!(j.finished);
    }

    #[test]
    fn tolerates_a_torn_final_line_only() {
        let good = [started_line(), planned(0, &[0])].join("\n");
        let torn = format!("{good}\n{}", &closed(0)[..20]);
        let j = Journal::parse_str(&torn).unwrap();
        assert_eq!(j.rounds.len(), 1);
        assert!(j.rounds[&0].close.is_none());

        let interior = format!("{}\n{}\n{}", started_line(), &closed(0)[..20], planned(1, &[]));
        assert!(Journal::parse_str(&interior).is_err(), "torn interior line");
    }

    #[test]
    fn run_resumed_prunes_the_superseded_suffix() {
        let snap = Event::Snapshot(Box::new(SnapshotState {
            next_round: 2,
            params: vec![],
            strategy_state: vec![],
            cum_bits: 0.0,
            cum_downlink_bits: 0.0,
            cum_sim_seconds: 0.0,
            cum_energy_joules: 0.0,
            workers: vec![],
        }))
        .encode();
        let text = [
            started_line(),
            planned(0, &[0]),
            closed(0),
            planned(1, &[1]),
            closed(1),
            snap,
            planned(2, &[0]),
            closed(2),
            Event::RunResumed { at_round: 2 }.encode(),
            planned(2, &[0]),
        ]
        .join("\n");
        let j = Journal::parse_str(&text).unwrap();
        assert_eq!(j.resume_round(), 2, "snapshot at next_round=2 survives");
        assert!(j.rounds[&2].close.is_none(), "re-planned round 2 wins");
        assert!(!j.finished);
    }

    #[test]
    fn rejects_missing_or_duplicate_preamble() {
        assert!(Journal::parse_str("").is_err());
        assert!(Journal::parse_str(&planned(0, &[])).is_err());
        let twice = format!("{}\n{}", started_line(), started_line());
        assert!(Journal::parse_str(&twice).is_err());
    }

    #[test]
    fn records_before_collects_eval_rounds_in_order() {
        let record = |round: usize| crate::metrics::RoundRecord {
            round,
            train_loss: 1.0,
            test_loss: 2.0,
            test_acc: 0.5,
            cum_bits: 0.0,
            cum_downlink_bits: 0.0,
            cum_sim_seconds: 0.0,
            cum_energy_joules: 0.0,
            host_ms: 0.0,
        };
        let close = |round: u64, rec: Option<usize>| {
            Event::RoundClosed(Box::new(RoundClose {
                round,
                outcome: vec![],
                round_seconds: 0.0,
                energy_joules: 0.0,
                uplink_bits: 0,
                downlink_bits: 0,
                bcast_seconds: 0.0,
                phase_start_seconds: 0.0,
                ready_seconds: vec![],
                finish_seconds: vec![],
                new_dead: vec![],
                host_phase_ms: vec![],
                record: rec.map(record),
            }))
            .encode()
        };
        let text = [
            started_line(),
            planned(0, &[0]),
            close(0, Some(0)),
            planned(1, &[0]),
            close(1, None),
            planned(2, &[0]),
            close(2, Some(2)),
        ]
        .join("\n");
        let j = Journal::parse_str(&text).unwrap();
        let recs = j.records_before(3);
        assert_eq!(recs.len(), 2);
        assert_eq!((recs[0].round, recs[1].round), (0, 2));
        assert_eq!(j.records_before(1).len(), 1);
    }
}

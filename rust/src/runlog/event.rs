//! The versioned journal event vocabulary: one event per JSONL line.
//!
//! Every line is an object `{"v": 1, "ev": "<type>", ...}`. The schema
//! version `v` covers the whole vocabulary: a reader accepts any `v` up to
//! its own [`SCHEMA_VERSION`] (same-version readers know every event type,
//! so an unknown `ev` is corruption, not a forward-compat case) and
//! refuses newer journals outright. Additive changes that old readers may
//! safely ignore do NOT bump the version; anything a replay must not
//! silently miss does.
//!
//! Wire spellings: floats print through the bit-exact JSON writer
//! ([`super::json`]); non-finite floats are `null` (read back as NaN);
//! byte blobs (strategy state) and `f32` parameter vectors ride as
//! lowercase hex of their little-endian bytes; `Delivery` outcomes
//! compress to one-letter codes `"D"`/`"T"`/`"N"`/`"R"`.

use super::json::{self, Json};
use crate::error::{Error, Result};
use crate::metrics::RoundRecord;
use crate::simnet::Delivery;

/// Version written to every event line by this build.
pub const SCHEMA_VERSION: u64 = 1;

/// Run preamble: everything needed to rebuild the engine from scratch.
#[derive(Debug, Clone, PartialEq)]
pub struct RunStarted {
    /// `"sequential"` or `"distributed"`.
    pub engine: String,
    /// Backend name as printed by `BackendKind::name()`.
    pub backend: String,
    /// The run seed every engine RNG stream derives from.
    pub run_seed: u64,
    /// The full experiment config, serialized through
    /// `ExperimentConfig::to_toml_string` — replay re-parses it.
    pub config_toml: String,
}

/// Everything one closed round contributes to replay and reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundClose {
    /// The round being closed.
    pub round: u64,
    /// Per-active-slot delivery outcome, in `RoundPlanned.active` order.
    pub outcome: Vec<Delivery>,
    /// Simulated duration of this round (paper eq. 12 clock).
    pub round_seconds: f64,
    /// Simulated energy this round spent across the fleet.
    pub energy_joules: f64,
    /// Uplink bits this round put on the air.
    pub uplink_bits: u64,
    /// Downlink bits this round broadcast.
    pub downlink_bits: u64,
    /// Phase timings captured by the simnet (see `RoundReport`).
    pub bcast_seconds: f64,
    /// Virtual-clock time at which this round's phases began.
    pub phase_start_seconds: f64,
    /// Per-slot compute-finish time; NaN for clients that never computed.
    pub ready_seconds: Vec<f64>,
    /// Per-slot would-be upload-finish time; NaN for non-transmitting slots.
    pub finish_seconds: Vec<f64>,
    /// Clients that died this round (distributed fault layer) — refusals
    /// are not script-derivable, so replay needs the recorded ids.
    pub new_dead: Vec<usize>,
    /// Host-side wall time per round phase (`telemetry::PHASE_NAMES`
    /// order, milliseconds), drained from the telemetry spans. Empty —
    /// and omitted from the line — unless `FEDSCALAR_TELEMETRY=1`, so
    /// journals stay byte-identical with telemetry off. Advisory only:
    /// replay ignores it, `fedscalar report` shows it.
    pub host_phase_ms: Vec<f64>,
    /// The evaluated metrics record, present on eval rounds only.
    pub record: Option<RoundRecord>,
}

/// One worker's resume state inside a [`SnapshotState`].
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerState {
    /// The worker's strategy blob (`Strategy::save_state`).
    pub strategy_state: Vec<u8>,
    /// Rounds this worker actually computed (drives its RNG position).
    pub rounds_computed: u64,
}

/// Periodic full-state snapshot: replay fast-forwards the cheap streams
/// (RNG, clocks, batteries) and restores the expensive state from here.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotState {
    /// The first round NOT covered by this snapshot.
    pub next_round: u64,
    /// Global model parameters at the boundary.
    pub params: Vec<f32>,
    /// Server-side strategy blob (`Strategy::save_state`).
    pub strategy_state: Vec<u8>,
    /// Cumulative uplink bits through the boundary.
    pub cum_bits: f64,
    /// Cumulative downlink bits.
    pub cum_downlink_bits: f64,
    /// Cumulative simulated seconds.
    pub cum_sim_seconds: f64,
    /// Cumulative simulated joules.
    pub cum_energy_joules: f64,
    /// Per-client worker state; empty for the sequential engine.
    pub workers: Vec<WorkerState>,
}

/// One journal event — one line in the log file.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// The run preamble (always the first line).
    RunStarted(RunStarted),
    /// A round opened with this active set.
    RoundPlanned {
        /// The opening round.
        round: u64,
        /// Selected client ids, in selection order.
        active: Vec<usize>,
    },
    /// A round closed (boxed: the close record is large).
    RoundClosed(Box<RoundClose>),
    /// A periodic full-state snapshot.
    Snapshot(Box<SnapshotState>),
    /// A resume re-attached to this journal.
    RunResumed {
        /// First round the continuation ran.
        at_round: u64,
    },
    /// The run completed all its rounds.
    RunFinished {
        /// Total rounds the run executed.
        rounds: u64,
    },
}

impl Event {
    /// Serialize to one JSONL line (no trailing newline).
    pub fn encode(&self) -> String {
        self.to_json().to_json_string()
    }

    fn to_json(&self) -> Json {
        let mut fields: Vec<(String, Json)> = vec![
            ("v".into(), unum(SCHEMA_VERSION)),
            ("ev".into(), Json::Str(self.name().into())),
        ];
        match self {
            Event::RunStarted(s) => {
                fields.push(("engine".into(), Json::Str(s.engine.clone())));
                fields.push(("backend".into(), Json::Str(s.backend.clone())));
                fields.push(("run_seed".into(), unum(s.run_seed)));
                fields.push(("config_toml".into(), Json::Str(s.config_toml.clone())));
            }
            Event::RoundPlanned { round, active } => {
                fields.push(("round".into(), unum(*round)));
                fields.push(("active".into(), usize_arr_json(active)));
            }
            Event::RoundClosed(c) => {
                fields.push(("round".into(), unum(c.round)));
                let codes = c
                    .outcome
                    .iter()
                    .map(|d| Json::Str(delivery_code(*d).into()))
                    .collect();
                fields.push(("outcome".into(), Json::Arr(codes)));
                fields.push(("round_seconds".into(), Json::Num(c.round_seconds)));
                fields.push(("energy_joules".into(), Json::Num(c.energy_joules)));
                fields.push(("uplink_bits".into(), unum(c.uplink_bits)));
                fields.push(("downlink_bits".into(), unum(c.downlink_bits)));
                fields.push(("bcast_seconds".into(), Json::Num(c.bcast_seconds)));
                fields.push((
                    "phase_start_seconds".into(),
                    Json::Num(c.phase_start_seconds),
                ));
                fields.push(("ready_seconds".into(), f64_arr_json(&c.ready_seconds)));
                fields.push(("finish_seconds".into(), f64_arr_json(&c.finish_seconds)));
                if !c.new_dead.is_empty() {
                    fields.push(("new_dead".into(), usize_arr_json(&c.new_dead)));
                }
                if !c.host_phase_ms.is_empty() {
                    fields.push(("host_phase_ms".into(), f64_arr_json(&c.host_phase_ms)));
                }
                if let Some(r) = &c.record {
                    fields.push(("record".into(), record_json(r)));
                }
            }
            Event::Snapshot(s) => {
                fields.push(("next_round".into(), unum(s.next_round)));
                fields.push(("params".into(), Json::Str(params_encode(&s.params))));
                fields.push((
                    "strategy_state".into(),
                    Json::Str(hex_encode(&s.strategy_state)),
                ));
                fields.push(("cum_bits".into(), Json::Num(s.cum_bits)));
                fields.push(("cum_downlink_bits".into(), Json::Num(s.cum_downlink_bits)));
                fields.push(("cum_sim_seconds".into(), Json::Num(s.cum_sim_seconds)));
                fields.push(("cum_energy_joules".into(), Json::Num(s.cum_energy_joules)));
                let workers = s
                    .workers
                    .iter()
                    .map(|w| {
                        Json::Obj(vec![
                            (
                                "strategy_state".into(),
                                Json::Str(hex_encode(&w.strategy_state)),
                            ),
                            ("rounds_computed".into(), unum(w.rounds_computed)),
                        ])
                    })
                    .collect();
                fields.push(("workers".into(), Json::Arr(workers)));
            }
            Event::RunResumed { at_round } => {
                fields.push(("at_round".into(), unum(*at_round)));
            }
            Event::RunFinished { rounds } => {
                fields.push(("rounds".into(), unum(*rounds)));
            }
        }
        Json::Obj(fields)
    }

    fn name(&self) -> &'static str {
        match self {
            Event::RunStarted(_) => "RunStarted",
            Event::RoundPlanned { .. } => "RoundPlanned",
            Event::RoundClosed(_) => "RoundClosed",
            Event::Snapshot(_) => "Snapshot",
            Event::RunResumed { .. } => "RunResumed",
            Event::RunFinished { .. } => "RunFinished",
        }
    }

    /// Parse one JSONL line.
    pub fn decode(line: &str) -> Result<Event> {
        let j = json::parse(line)?;
        let v = u64_of(&j, "v")?;
        if v > SCHEMA_VERSION {
            return Err(Error::config(format!(
                "journal schema v{v} is newer than this build (v{SCHEMA_VERSION}) — \
                 upgrade fedscalar to read it"
            )));
        }
        let ev = str_of(&j, "ev")?;
        match ev.as_str() {
            "RunStarted" => Ok(Event::RunStarted(RunStarted {
                engine: str_of(&j, "engine")?,
                backend: str_of(&j, "backend")?,
                run_seed: u64_of(&j, "run_seed")?,
                config_toml: str_of(&j, "config_toml")?,
            })),
            "RoundPlanned" => Ok(Event::RoundPlanned {
                round: u64_of(&j, "round")?,
                active: usize_arr_of(&j, "active")?,
            }),
            "RoundClosed" => {
                let outcome = field(&j, "outcome")?
                    .as_arr()
                    .ok_or_else(|| bad_field("outcome"))?
                    .iter()
                    .map(|item| {
                        item.as_str()
                            .ok_or_else(|| bad_field("outcome"))
                            .and_then(delivery_parse)
                    })
                    .collect::<Result<Vec<_>>>()?;
                let record = match j.get("record") {
                    Some(r) => Some(record_parse(r)?),
                    None => None,
                };
                Ok(Event::RoundClosed(Box::new(RoundClose {
                    round: u64_of(&j, "round")?,
                    outcome,
                    round_seconds: f64_of(&j, "round_seconds")?,
                    energy_joules: f64_of(&j, "energy_joules")?,
                    uplink_bits: u64_of(&j, "uplink_bits")?,
                    downlink_bits: u64_of(&j, "downlink_bits")?,
                    bcast_seconds: f64_of(&j, "bcast_seconds")?,
                    phase_start_seconds: f64_of(&j, "phase_start_seconds")?,
                    ready_seconds: f64_arr_of(&j, "ready_seconds")?,
                    finish_seconds: f64_arr_of(&j, "finish_seconds")?,
                    new_dead: match j.get("new_dead") {
                        Some(_) => usize_arr_of(&j, "new_dead")?,
                        None => Vec::new(),
                    },
                    host_phase_ms: match j.get("host_phase_ms") {
                        Some(_) => f64_arr_of(&j, "host_phase_ms")?,
                        None => Vec::new(),
                    },
                    record,
                })))
            }
            "Snapshot" => {
                let workers = field(&j, "workers")?
                    .as_arr()
                    .ok_or_else(|| bad_field("workers"))?
                    .iter()
                    .map(|w| {
                        Ok(WorkerState {
                            strategy_state: hex_decode(&str_of(w, "strategy_state")?)?,
                            rounds_computed: u64_of(w, "rounds_computed")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Event::Snapshot(Box::new(SnapshotState {
                    next_round: u64_of(&j, "next_round")?,
                    params: params_decode(&str_of(&j, "params")?)?,
                    strategy_state: hex_decode(&str_of(&j, "strategy_state")?)?,
                    cum_bits: f64_of(&j, "cum_bits")?,
                    cum_downlink_bits: f64_of(&j, "cum_downlink_bits")?,
                    cum_sim_seconds: f64_of(&j, "cum_sim_seconds")?,
                    cum_energy_joules: f64_of(&j, "cum_energy_joules")?,
                    workers,
                })))
            }
            "RunResumed" => Ok(Event::RunResumed {
                at_round: u64_of(&j, "at_round")?,
            }),
            "RunFinished" => Ok(Event::RunFinished {
                rounds: u64_of(&j, "rounds")?,
            }),
            other => Err(Error::invariant(format!(
                "journal v{v} contains unknown event `{other}` — corrupt or hand-edited log"
            ))),
        }
    }
}

fn delivery_code(d: Delivery) -> &'static str {
    match d {
        Delivery::Delivered => "D",
        Delivery::TransmittedDropped => "T",
        Delivery::NeverStarted => "N",
        Delivery::Rejected => "R",
    }
}

fn delivery_parse(code: &str) -> Result<Delivery> {
    match code {
        "D" => Ok(Delivery::Delivered),
        "T" => Ok(Delivery::TransmittedDropped),
        "N" => Ok(Delivery::NeverStarted),
        "R" => Ok(Delivery::Rejected),
        other => Err(Error::invariant(format!(
            "journal: unknown delivery code `{other}`"
        ))),
    }
}

fn record_json(r: &RoundRecord) -> Json {
    Json::Obj(vec![
        ("round".into(), unum(r.round as u64)),
        ("train_loss".into(), Json::Num(r.train_loss)),
        ("test_loss".into(), Json::Num(r.test_loss)),
        ("test_acc".into(), Json::Num(r.test_acc)),
        ("cum_bits".into(), Json::Num(r.cum_bits)),
        ("cum_downlink_bits".into(), Json::Num(r.cum_downlink_bits)),
        ("cum_sim_seconds".into(), Json::Num(r.cum_sim_seconds)),
        ("cum_energy_joules".into(), Json::Num(r.cum_energy_joules)),
        ("host_ms".into(), Json::Num(r.host_ms)),
    ])
}

fn record_parse(j: &Json) -> Result<RoundRecord> {
    Ok(RoundRecord {
        round: usize_of(j, "round")?,
        train_loss: f64_of(j, "train_loss")?,
        test_loss: f64_of(j, "test_loss")?,
        test_acc: f64_of(j, "test_acc")?,
        cum_bits: f64_of(j, "cum_bits")?,
        cum_downlink_bits: f64_of(j, "cum_downlink_bits")?,
        cum_sim_seconds: f64_of(j, "cum_sim_seconds")?,
        cum_energy_joules: f64_of(j, "cum_energy_joules")?,
        host_ms: f64_of(j, "host_ms")?,
    })
}

// --- field accessors -----------------------------------------------------

fn bad_field(key: &str) -> Error {
    Error::invariant(format!("journal event: bad or missing field `{key}`"))
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| bad_field(key))
}

fn f64_of(j: &Json, key: &str) -> Result<f64> {
    field(j, key)?.as_f64().ok_or_else(|| bad_field(key))
}

fn u64_of(j: &Json, key: &str) -> Result<u64> {
    let v = f64_of(j, key)?;
    if (0.0..=9.007_199_254_740_992e15).contains(&v) && v.fract() == 0.0 {
        Ok(v as u64)
    } else {
        Err(bad_field(key))
    }
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    Ok(u64_of(j, key)? as usize)
}

fn str_of(j: &Json, key: &str) -> Result<String> {
    Ok(field(j, key)?
        .as_str()
        .ok_or_else(|| bad_field(key))?
        .to_string())
}

fn f64_arr_of(j: &Json, key: &str) -> Result<Vec<f64>> {
    field(j, key)?
        .as_arr()
        .ok_or_else(|| bad_field(key))?
        .iter()
        .map(|item| item.as_f64().ok_or_else(|| bad_field(key)))
        .collect()
}

fn usize_arr_of(j: &Json, key: &str) -> Result<Vec<usize>> {
    field(j, key)?
        .as_arr()
        .ok_or_else(|| bad_field(key))?
        .iter()
        .map(|item| match item.as_f64() {
            Some(v) if v >= 0.0 && v.fract() == 0.0 => Ok(v as usize),
            _ => Err(bad_field(key)),
        })
        .collect()
}

fn unum(v: u64) -> Json {
    Json::Num(v as f64)
}

fn f64_arr_json(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn usize_arr_json(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| unum(x as u64)).collect())
}

// --- hex blobs -----------------------------------------------------------

/// Lowercase hex encoding for opaque blobs (strategy state, params are
/// not hexed — only byte blobs ride this way).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = std::fmt::Write::write_fmt(&mut out, format_args!("{b:02x}"));
    }
    out
}

/// Inverse of [`hex_encode`]; rejects odd lengths and non-hex bytes.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return Err(Error::invariant("journal: odd-length hex blob"));
    }
    let nib = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(Error::invariant("journal: non-hex byte in blob")),
        }
    };
    b.chunks_exact(2)
        .map(|pair| Ok((nib(pair[0])? << 4) | nib(pair[1])?))
        .collect()
}

fn params_encode(params: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    hex_encode(&bytes)
}

fn params_decode(s: &str) -> Result<Vec<f32>> {
    let bytes = hex_decode(s)?;
    if bytes.len() % 4 != 0 {
        return Err(Error::invariant("journal: params blob not a multiple of 4"));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(ev: &Event) -> Event {
        Event::decode(&ev.encode()).expect("event round-trip")
    }

    fn sample_record(round: usize, train_loss: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss,
            test_loss: 0.1 + 0.2,
            test_acc: 1.0 / 3.0,
            cum_bits: 1.25e7,
            cum_downlink_bits: 9.6e8,
            cum_sim_seconds: 488.123456789,
            cum_energy_joules: 20.4,
            host_ms: 3.25,
        }
    }

    #[test]
    fn run_started_round_trips() {
        let ev = Event::RunStarted(RunStarted {
            engine: "sequential".into(),
            backend: "pure-rust".into(),
            run_seed: 0xdead_beef,
            config_toml: "[fed]\nnum_agents = 6\nmethod = \"topk16\"\n".into(),
        });
        assert_eq!(roundtrip(&ev), ev);
    }

    #[test]
    fn round_planned_round_trips() {
        let ev = Event::RoundPlanned {
            round: 7,
            active: vec![0, 3, 5],
        };
        assert_eq!(roundtrip(&ev), ev);
        let empty = Event::RoundPlanned {
            round: 8,
            active: vec![],
        };
        assert_eq!(roundtrip(&empty), empty);
    }

    #[test]
    fn round_closed_round_trips_including_nans() {
        let ev = Event::RoundClosed(Box::new(RoundClose {
            round: 12,
            outcome: vec![
                Delivery::Delivered,
                Delivery::TransmittedDropped,
                Delivery::NeverStarted,
                Delivery::Rejected,
            ],
            round_seconds: 3.0625,
            energy_joules: 0.75,
            uplink_bits: 1234,
            downlink_bits: 567_890,
            bcast_seconds: 0.5,
            phase_start_seconds: 1.5,
            ready_seconds: vec![1.25, 1.5, f64::NAN, 1.75],
            finish_seconds: vec![2.0, f64::NAN, f64::NAN, 2.25],
            new_dead: vec![4],
            host_phase_ms: vec![0.5, 0.0, 12.25, 0.0, 1.5, 0.125, 3.0],
            record: Some(sample_record(12, f64::NAN)),
        }));
        let back = roundtrip(&ev);
        let (a, b) = match (&ev, &back) {
            (Event::RoundClosed(a), Event::RoundClosed(b)) => (a, b),
            _ => panic!("variant changed"),
        };
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.new_dead, b.new_dead);
        assert_eq!(a.host_phase_ms, b.host_phase_ms);
        assert!(b.ready_seconds[2].is_nan() && b.finish_seconds[1].is_nan());
        assert_eq!(a.ready_seconds[..2], b.ready_seconds[..2]);
        let (ra, rb) = (a.record.as_ref().unwrap(), b.record.as_ref().unwrap());
        assert!(rb.train_loss.is_nan());
        assert_eq!(ra.test_loss.to_bits(), rb.test_loss.to_bits());
        assert_eq!(ra.cum_sim_seconds.to_bits(), rb.cum_sim_seconds.to_bits());
    }

    #[test]
    fn round_closed_minimal_omits_optional_fields() {
        let ev = Event::RoundClosed(Box::new(RoundClose {
            round: 0,
            outcome: vec![],
            round_seconds: 0.0,
            energy_joules: 0.0,
            uplink_bits: 0,
            downlink_bits: 0,
            bcast_seconds: 0.0,
            phase_start_seconds: 0.0,
            ready_seconds: vec![],
            finish_seconds: vec![],
            new_dead: vec![],
            host_phase_ms: vec![],
            record: None,
        }));
        let line = ev.encode();
        assert!(!line.contains("new_dead") && !line.contains("record"));
        assert!(!line.contains("host_phase_ms"));
        assert_eq!(roundtrip(&ev), ev);
    }

    #[test]
    fn snapshot_round_trips_params_bit_exact() {
        let ev = Event::Snapshot(Box::new(SnapshotState {
            next_round: 10,
            params: vec![0.1f32, -2.5, f32::MIN_POSITIVE, 1.0e30],
            strategy_state: vec![0, 1, 254, 255, 16],
            cum_bits: 1e7 + 0.5,
            cum_downlink_bits: 2.0,
            cum_sim_seconds: 3.0,
            cum_energy_joules: 4.0,
            workers: vec![
                WorkerState {
                    strategy_state: vec![],
                    rounds_computed: 0,
                },
                WorkerState {
                    strategy_state: vec![9, 8, 7],
                    rounds_computed: 5,
                },
            ],
        }));
        assert_eq!(roundtrip(&ev), ev);
    }

    #[test]
    fn resume_and_finish_round_trip() {
        for ev in [
            Event::RunResumed { at_round: 15 },
            Event::RunFinished { rounds: 24 },
        ] {
            assert_eq!(roundtrip(&ev), ev);
        }
    }

    #[test]
    fn newer_schema_version_is_refused() {
        let line = r#"{"v":999,"ev":"RunFinished","rounds":1}"#;
        let err = Event::decode(line).unwrap_err().to_string();
        assert!(err.contains("newer"), "got: {err}");
    }

    #[test]
    fn unknown_event_and_missing_fields_error() {
        assert!(Event::decode(r#"{"v":1,"ev":"Mystery"}"#).is_err());
        assert!(Event::decode(r#"{"v":1,"ev":"RunResumed"}"#).is_err());
        assert!(Event::decode(r#"{"ev":"RunFinished","rounds":1}"#).is_err());
    }

    #[test]
    fn hex_blob_round_trips_and_rejects_garbage() {
        let all: Vec<u8> = (0..=255).collect();
        assert_eq!(hex_decode(&hex_encode(&all)).unwrap(), all);
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
    }
}

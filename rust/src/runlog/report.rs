//! Offline bottleneck analysis: `fedscalar report <log>` answers "which
//! client and which phase gated each round" from the journal alone.
//!
//! Each `RoundClosed` carries the simnet's phase timings:
//!
//! * `bcast_seconds` — the model broadcast (downlink);
//! * `phase_start_seconds` — when the upload phase opened, i.e. the
//!   *last* client became ready: `compute = phase_start - bcast`;
//! * `ready_seconds[i]` — when slot `i`'s client finished computing
//!   (the argmax is the compute-critical client);
//! * `finish_seconds[i]` — when slot `i`'s upload would land, deadline
//!   or not (the argmax among transmitters is the upload-critical
//!   client).
//!
//! A round's gating phase is the largest of its three segments — unless
//! the deadline cut someone, which the report surfaces first: a dropped
//! upload wastes the whole round's airtime and energy for that client,
//! so it dominates any within-deadline breakdown.
//!
//! Runs journaled under `FEDSCALAR_TELEMETRY=1` additionally carry
//! host-side phase timings (`RoundClose.host_phase_ms`, from the
//! telemetry spans); the `host_s(phase)` column puts real wall time next
//! to the simulated clock, so a round the simnet calls upload-bound but
//! the host spent decoding is visible at a glance. `-` when the run was
//! not instrumented.

use crate::runlog::Journal;
use crate::telemetry::PHASE_NAMES;
use std::fmt::Write;

/// Largest non-NaN entry's index, or `None` if all are NaN/empty.
fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.is_none_or(|(_, b)| x > b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

fn join_ids(ids: &[usize]) -> String {
    ids.iter()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

/// `total_host_seconds(dominant_phase)` from a round's span timings, or
/// `-` for rounds journaled without telemetry.
fn host_column(host_phase_ms: &[f64]) -> String {
    if host_phase_ms.is_empty() {
        return "-".to_string();
    }
    let total_s: f64 = host_phase_ms.iter().sum::<f64>() / 1e3;
    let gate = argmax(host_phase_ms)
        .and_then(|i| PHASE_NAMES.get(i))
        .copied()
        .unwrap_or("-");
    format!("{total_s:.4}({gate})")
}

/// Render the per-round phase breakdown plus cumulative tallies.
pub fn render(j: &Journal) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "run: engine={} backend={} seed={}{}",
        j.start.engine,
        j.start.backend,
        j.start.run_seed,
        if j.finished { "" } else { " (unfinished)" }
    );
    let _ = writeln!(
        out,
        "{:>6}  {:<9} {:>10} {:>10} {:>10} {:>10} {:>16}  {}",
        "round", "phase", "bcast_s", "compute_s", "upload_s", "total_s", "host_s(phase)", "critical"
    );

    let (mut up_bits, mut down_bits) = (0u64, 0u64);
    let (mut sim_s, mut energy_j) = (0.0f64, 0.0f64);
    let (mut delivered, mut dropped, mut deaths, mut idle) = (0u64, 0u64, 0u64, 0u64);

    for (&k, entry) in &j.rounds {
        let Some(close) = &entry.close else {
            let _ = writeln!(out, "{k:>6}  (round never closed — crash tail)");
            continue;
        };
        up_bits += close.uplink_bits;
        down_bits += close.downlink_bits;
        sim_s += close.round_seconds;
        energy_j += close.energy_joules;
        deaths += close.new_dead.len() as u64;
        if entry.active.is_empty() {
            idle += 1;
            let _ = writeln!(out, "{k:>6}  idle");
            continue;
        }
        let drops: Vec<usize> = entry
            .active
            .iter()
            .zip(&close.outcome)
            .filter(|(_, o)| !o.delivered())
            .map(|(&c, _)| c)
            .collect();
        delivered += (entry.active.len() - drops.len()) as u64;
        dropped += drops.len() as u64;

        let bcast = close.bcast_seconds;
        let compute = (close.phase_start_seconds - close.bcast_seconds).max(0.0);
        let upload = (close.round_seconds - close.phase_start_seconds).max(0.0);
        let (phase, critical) = if !drops.is_empty() {
            ("deadline", format!("dropped: {}", join_ids(&drops)))
        } else if bcast >= compute && bcast >= upload {
            ("bcast", "-".to_string())
        } else if compute >= upload {
            let who = argmax(&close.ready_seconds)
                .and_then(|i| entry.active.get(i))
                .map_or("-".to_string(), |c| format!("client {c}"));
            ("compute", who)
        } else {
            let who = argmax(&close.finish_seconds)
                .and_then(|i| entry.active.get(i))
                .map_or("-".to_string(), |c| format!("client {c}"));
            ("upload", who)
        };
        let dead_note = if close.new_dead.is_empty() {
            String::new()
        } else {
            format!("  [dead: {}]", join_ids(&close.new_dead))
        };
        let host = host_column(&close.host_phase_ms);
        let _ = writeln!(
            out,
            "{k:>6}  {phase:<9} {bcast:>10.4} {compute:>10.4} {upload:>10.4} {:>10.4} {host:>16}  {critical}{dead_note}",
            close.round_seconds
        );
    }

    let _ = writeln!(
        out,
        "\ntotals: rounds={} (idle {idle})  delivered={delivered}  dropped={dropped}  dead={deaths}",
        j.rounds.len()
    );
    let _ = writeln!(
        out,
        "        uplink={up_bits} bits  downlink={down_bits} bits  sim_time={sim_s:.4} s  energy={energy_j:.4} J"
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runlog::{Event, RoundClose, RunStarted};
    use crate::simnet::Delivery;

    fn close(round: u64, outcome: Vec<Delivery>, timings: (f64, f64, f64)) -> RoundClose {
        let (bcast, phase_start, total) = timings;
        RoundClose {
            round,
            outcome,
            round_seconds: total,
            energy_joules: 1.5,
            uplink_bits: 100,
            downlink_bits: 200,
            bcast_seconds: bcast,
            phase_start_seconds: phase_start,
            ready_seconds: vec![],
            finish_seconds: vec![],
            new_dead: vec![],
            host_phase_ms: vec![],
            record: None,
        }
    }

    #[test]
    fn names_the_gating_phase_and_critical_client() {
        let mut upload_round = close(0, vec![Delivery::Delivered; 2], (0.1, 0.5, 2.0));
        upload_round.ready_seconds = vec![0.5, 0.4];
        upload_round.finish_seconds = vec![1.2, 2.0];
        let deadline_round = close(
            1,
            vec![Delivery::Delivered, Delivery::TransmittedDropped],
            (0.1, 0.2, 0.9),
        );
        let lines = [
            Event::RunStarted(RunStarted {
                engine: "sequential".into(),
                backend: "pure-rust".into(),
                run_seed: 5,
                config_toml: String::new(),
            })
            .encode(),
            Event::RoundPlanned {
                round: 0,
                active: vec![3, 7],
            }
            .encode(),
            Event::RoundClosed(Box::new(upload_round)).encode(),
            Event::RoundPlanned {
                round: 1,
                active: vec![2, 5],
            }
            .encode(),
            Event::RoundClosed(Box::new(deadline_round)).encode(),
            Event::RoundPlanned {
                round: 2,
                active: vec![],
            }
            .encode(),
            Event::RoundClosed(Box::new(close(2, vec![], (0.0, 0.0, 0.0)))).encode(),
        ]
        .join("\n");
        let j = Journal::parse_str(&lines).unwrap();
        let text = render(&j);
        // round 0: upload segment (1.5s) dominates; slot 1 = client 7
        // finishes last
        assert!(text.contains("upload"), "{text}");
        assert!(text.contains("client 7"), "{text}");
        // round 1: the drop outranks any segment; slot 1 = client 5
        assert!(text.contains("deadline"), "{text}");
        assert!(text.contains("dropped: 5"), "{text}");
        // round 2: idle
        assert!(text.contains("idle"), "{text}");
        assert!(text.contains("delivered=3"), "{text}");
        assert!(text.contains("dropped=1"), "{text}");
    }

    #[test]
    fn compute_bound_round_names_the_slowest_client() {
        let mut c = close(0, vec![Delivery::Delivered; 2], (0.1, 1.4, 1.6));
        c.ready_seconds = vec![1.4, 0.6];
        c.finish_seconds = vec![1.5, 1.6];
        let lines = [
            Event::RunStarted(RunStarted {
                engine: "sequential".into(),
                backend: "pure-rust".into(),
                run_seed: 5,
                config_toml: String::new(),
            })
            .encode(),
            Event::RoundPlanned {
                round: 0,
                active: vec![4, 9],
            }
            .encode(),
            Event::RoundClosed(Box::new(c)).encode(),
        ]
        .join("\n");
        let text = render(&Journal::parse_str(&lines).unwrap());
        assert!(text.contains("compute"), "{text}");
        assert!(text.contains("client 4"), "{text}");
    }

    #[test]
    fn host_column_shows_total_and_dominant_phase() {
        let mut with_host = close(0, vec![Delivery::Delivered], (0.1, 0.2, 0.3));
        // select/broadcast/compute/encode/decode/apply/eval, ms
        with_host.host_phase_ms = vec![1.0, 0.0, 40.0, 0.0, 2.0, 0.5, 6.0];
        let lines = [
            Event::RunStarted(RunStarted {
                engine: "sequential".into(),
                backend: "pure-rust".into(),
                run_seed: 1,
                config_toml: String::new(),
            })
            .encode(),
            Event::RoundPlanned {
                round: 0,
                active: vec![2],
            }
            .encode(),
            Event::RoundClosed(Box::new(with_host)).encode(),
            Event::RoundPlanned {
                round: 1,
                active: vec![2],
            }
            .encode(),
            Event::RoundClosed(Box::new(close(1, vec![Delivery::Delivered], (0.1, 0.2, 0.3))))
                .encode(),
        ]
        .join("\n");
        let text = render(&Journal::parse_str(&lines).unwrap());
        // 49.5 ms total, compute dominates
        assert!(text.contains("0.0495(compute)"), "{text}");
        // the uninstrumented round renders a placeholder, not zeros
        assert!(text.contains(" -  "), "{text}");
    }
}

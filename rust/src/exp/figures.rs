//! Figures 2-6: the four-method comparison suite (FedScalar-Normal,
//! FedScalar-Rademacher, FedAvg, QSGD-8bit) on the Digits task, averaged
//! over multiple runs, with bits / simulated-time / energy on the x-axes.
//!
//! All five figures are projections of one underlying sweep, so the suite
//! runs it once and every bench/CLI target projects what it needs.

use crate::algo::Method;
use crate::config::ExperimentConfig;
use crate::coordinator::engine::{Engine, RunOutput};
use crate::error::{Error, Result};
use crate::metrics::{average_runs, RunHistory};
use crate::runtime::{Backend, PureRustBackend, XlaBackend};
use crate::util::stats;
use std::path::PathBuf;

/// Which backend executes the compute stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    PureRust,
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "pure-rust" | "purerust" | "rust" => Some(BackendKind::PureRust),
            "xla" | "pjrt" => Some(BackendKind::Xla),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::PureRust => "pure-rust",
            BackendKind::Xla => "xla-pjrt",
        }
    }
}

/// Build a backend for `cfg`.
pub fn make_backend(kind: BackendKind, cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::PureRust => {
            let mut be = PureRustBackend::new(&cfg.model);
            be.set_shape(cfg.fed.local_steps, cfg.fed.batch_size);
            Ok(Box::new(be))
        }
        BackendKind::Xla => {
            let be = XlaBackend::load(&cfg.artifacts_dir)?;
            be.manifest().check_compatible(
                cfg.model.param_dim(),
                cfg.fed.num_agents,
                cfg.fed.local_steps,
                cfg.fed.batch_size,
            )?;
            Ok(Box::new(be))
        }
    }
}

#[derive(Debug, Clone)]
pub struct SuiteOptions {
    pub methods: Vec<Method>,
    pub runs: usize,
    pub backend: BackendKind,
    /// Write per-method CSVs under this directory (None = don't write).
    pub out_dir: Option<PathBuf>,
    /// Parallelize across runs (PureRust only; PJRT handles are !Send).
    pub parallel: bool,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        SuiteOptions {
            methods: Method::paper_set().to_vec(),
            runs: 10,
            backend: BackendKind::PureRust,
            out_dir: Some(PathBuf::from("results")),
            parallel: true,
        }
    }
}

/// The averaged history per method.
#[derive(Debug, Clone)]
pub struct FigureSuite {
    pub per_method: Vec<(Method, RunHistory)>,
    pub runs: usize,
}

/// Run the full comparison suite.
pub fn run_figure_suite(base: &ExperimentConfig, opts: &SuiteOptions) -> Result<FigureSuite> {
    if opts.runs == 0 || opts.methods.is_empty() {
        return Err(Error::config("need >= 1 run and >= 1 method"));
    }
    let mut per_method = Vec::new();
    for method in &opts.methods {
        let mut cfg = base.clone();
        cfg.fed.method = method.clone();
        let runs = if opts.parallel && opts.backend == BackendKind::PureRust && opts.runs > 1 {
            run_many_parallel(&cfg, opts.runs)?
        } else {
            run_many_serial(&cfg, opts.backend, opts.runs)?
        };
        let avg = average_runs(&runs);
        if let Some(dir) = &opts.out_dir {
            avg.write_csv(dir.join(format!("{}.csv", method.name())))?;
        }
        per_method.push((method.clone(), avg));
    }
    Ok(FigureSuite {
        per_method,
        runs: opts.runs,
    })
}

fn run_many_serial(
    cfg: &ExperimentConfig,
    backend: BackendKind,
    runs: usize,
) -> Result<Vec<RunOutput>> {
    (0..runs)
        .map(|r| {
            let be = make_backend(backend, cfg)?;
            Engine::from_config(cfg, be, r as u64)?.run()
        })
        .collect()
}

/// Work-stealing run-level parallelism: each worker thread builds its own
/// PureRust backend + engine (everything it owns is Send), pulls run ids
/// from a shared counter, and writes into its result slot.
fn run_many_parallel(cfg: &ExperimentConfig, runs: usize) -> Result<Vec<RunOutput>> {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(runs);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results: std::sync::Mutex<Vec<Option<Result<RunOutput>>>> =
        std::sync::Mutex::new((0..runs).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let r = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if r >= runs {
                    break;
                }
                let out = (|| {
                    let be = make_backend(BackendKind::PureRust, cfg)?;
                    Engine::from_config(cfg, be, r as u64)?.run()
                })();
                results.lock().unwrap()[r] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every run slot filled"))
        .collect()
}

impl FigureSuite {
    pub fn history(&self, method: &Method) -> Option<&RunHistory> {
        self.per_method
            .iter()
            .find(|(m, _)| m == method)
            .map(|(_, h)| h)
    }

    /// Fig 2/3 style summary: per-method (final train loss, final acc).
    pub fn summary_rows(&self) -> Vec<(String, f64, f64)> {
        self.per_method
            .iter()
            .map(|(m, h)| (m.name(), h.final_train_loss(), h.final_accuracy()))
            .collect()
    }

    /// Fig 4/5/6 readout: accuracy at a given budget on the chosen axis.
    pub fn acc_at(&self, axis: Axis, budget: f64) -> Vec<(String, Option<f64>)> {
        self.per_method
            .iter()
            .map(|(m, h)| {
                let v = match axis {
                    Axis::Bits => h.acc_at_bits(budget),
                    Axis::TotalBits => h.acc_at_total_bits(budget),
                    Axis::Seconds => h.acc_at_seconds(budget),
                    Axis::Joules => h.acc_at_joules(budget),
                };
                (m.name(), v)
            })
            .collect()
    }

    /// Bits needed to reach an accuracy target (Fig 4 crossing readout).
    pub fn bits_to_accuracy(&self, target: f64) -> Vec<(String, Option<f64>)> {
        self.per_method
            .iter()
            .map(|(m, h)| {
                (
                    m.name(),
                    stats::first_crossing(
                        &h.series(|r| r.cum_bits),
                        &h.series(|r| r.test_acc),
                        target,
                    ),
                )
            })
            .collect()
    }
}

/// The budget axes of Figs 4, 5, 6 — plus the uplink+downlink total of
/// the symmetric communication cost model (Zheng et al., PAPERS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    Bits,
    TotalBits,
    Seconds,
    Joules,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::VDistribution;

    fn tiny_opts(runs: usize, parallel: bool) -> SuiteOptions {
        SuiteOptions {
            methods: vec![
                Method::fedscalar(VDistribution::Rademacher, 1),
                Method::fedavg(),
            ],
            runs,
            backend: BackendKind::PureRust,
            out_dir: None,
            parallel,
        }
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::smoke();
        cfg.fed.rounds = 8;
        cfg.fed.eval_every = 4;
        cfg.fed.num_agents = 3;
        cfg
    }

    #[test]
    fn suite_runs_and_summarizes() {
        let suite = run_figure_suite(&tiny_cfg(), &tiny_opts(2, false)).unwrap();
        assert_eq!(suite.per_method.len(), 2);
        let rows = suite.summary_rows();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|(_, l, a)| l.is_finite() && *a >= 0.0));
        // fedavg uploads many more bits than fedscalar in the same rounds
        let fs = suite.per_method[0].1.records.last().unwrap().cum_bits;
        let fa = suite.per_method[1].1.records.last().unwrap().cum_bits;
        assert!(fa > 100.0 * fs, "fa={fa} fs={fs}");
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = tiny_cfg();
        let s = run_figure_suite(&cfg, &tiny_opts(3, false)).unwrap();
        let p = run_figure_suite(&cfg, &tiny_opts(3, true)).unwrap();
        for ((m1, h1), (m2, h2)) in s.per_method.iter().zip(&p.per_method) {
            assert_eq!(m1, m2);
            assert!(
                crate::metrics::same_histories(h1, h2),
                "method {}",
                m1.name()
            );
        }
    }

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("rust"), Some(BackendKind::PureRust));
        assert_eq!(BackendKind::parse("gpu"), None);
    }

    #[test]
    fn empty_opts_rejected() {
        let mut o = tiny_opts(0, false);
        assert!(run_figure_suite(&tiny_cfg(), &o).is_err());
        o.runs = 1;
        o.methods.clear();
        assert!(run_figure_suite(&tiny_cfg(), &o).is_err());
    }
}

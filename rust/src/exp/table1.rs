//! Table I: total upload time for K = 500 rounds, d = 1,000 parameters,
//! N = 20 agents, across uplink bandwidths and schedules, against a
//! 1,200-second battery budget (the dagger cells).
//!
//! This is a closed-form latency computation — the paper's motivating
//! arithmetic — so our numbers must match the paper's *exactly*.

use crate::netsim::{upload_seconds, Schedule};

/// Paper Table I parameters.
pub const TABLE1_ROUNDS: usize = 500;
pub const TABLE1_DIM: usize = 1_000;
pub const TABLE1_AGENTS: usize = 20;
pub const TABLE1_BUDGET_S: f64 = 1_200.0;
pub const TABLE1_BANDWIDTHS_KBPS: [f64; 4] = [1.0, 10.0, 50.0, 100.0];

#[derive(Debug, Clone, PartialEq)]
pub struct Table1Row {
    pub bandwidth_kbps: f64,
    /// Per-agent upload time for one round (seconds) — the paper's
    /// "Upload Time/Round" column.
    pub upload_per_round_s: f64,
    /// Total over K rounds, concurrent schedule.
    pub concurrent_total_s: f64,
    pub concurrent_violates: bool,
    /// Total over K rounds, TDMA schedule (N sequential slots).
    pub tdma_total_s: f64,
    pub tdma_violates: bool,
}

/// Compute the full table for a given payload model (bits per agent-round).
pub fn table1_rows_for_bits(bits_per_agent_round: u64) -> Vec<Table1Row> {
    TABLE1_BANDWIDTHS_KBPS
        .iter()
        .map(|&kbps| {
            let rate = kbps * 1_000.0;
            let one = upload_seconds(bits_per_agent_round, rate);
            let per_agent = vec![one; TABLE1_AGENTS];
            let conc = Schedule::Concurrent.combine(&per_agent) * TABLE1_ROUNDS as f64;
            let tdma = Schedule::Tdma.combine(&per_agent) * TABLE1_ROUNDS as f64;
            Table1Row {
                bandwidth_kbps: kbps,
                upload_per_round_s: one,
                concurrent_total_s: conc,
                concurrent_violates: conc > TABLE1_BUDGET_S,
                tdma_total_s: tdma,
                tdma_violates: tdma > TABLE1_BUDGET_S,
            }
        })
        .collect()
}

/// The paper's Table I: FedAvg-style full-model upload (d 32-bit floats).
pub fn table1_rows() -> Vec<Table1Row> {
    table1_rows_for_bits((TABLE1_DIM as u64) * 32)
}

/// The same table under FedScalar's 64-bit payload — the comparison the
/// paper's §I argues for.
pub fn table1_rows_fedscalar() -> Vec<Table1Row> {
    table1_rows_for_bits(64)
}

/// Table I under ANY registered strategy's payload model at the table's
/// d = 1,000 — the accounting comes straight from
/// [`crate::algo::Strategy::uplink_bits`], so a strategy plugged in via
/// the registry gets its Table-I row for free.
pub fn table1_rows_for_method(method: &crate::algo::Method) -> Vec<Table1Row> {
    table1_rows_for_bits(method.uplink_bits(TABLE1_DIM))
}

/// Render rows in the paper's layout.
pub fn render(rows: &[Table1Row], title: &str) -> String {
    let mut s = format!(
        "{title}\nK={TABLE1_ROUNDS} rounds, d={TABLE1_DIM}, N={TABLE1_AGENTS}, budget={TABLE1_BUDGET_S} s  († = budget violation)\n\
         {:<12} {:>14} {:>22} {:>24}\n",
        "Bandwidth", "Upload/Round", "Concurrent", "TDMA (N=20)"
    );
    for r in rows {
        let fmt_total = |secs: f64, violates: bool| -> String {
            let tag = if violates { " †" } else { "  " };
            if secs >= 3600.0 {
                format!("{:.0} s ({:.1} h){tag}", secs, secs / 3600.0)
            } else if secs >= 60.0 {
                format!("{:.0} s ({:.1} min){tag}", secs, secs / 60.0)
            } else {
                format!("{:.2} s{tag}", secs)
            }
        };
        s.push_str(&format!(
            "{:<12} {:>12.2} s {:>22} {:>24}\n",
            format!("{} kbps", r.bandwidth_kbps),
            r.upload_per_round_s,
            fmt_total(r.concurrent_total_s, r.concurrent_violates),
            fmt_total(r.tdma_total_s, r.tdma_violates),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_exactly() {
        let rows = table1_rows();
        // paper row 1: 1 kbps -> 32 s/round, 16,000 s concurrent†, 320,000 s TDMA†
        assert!((rows[0].upload_per_round_s - 32.0).abs() < 1e-9);
        assert!((rows[0].concurrent_total_s - 16_000.0).abs() < 1e-6);
        assert!((rows[0].tdma_total_s - 320_000.0).abs() < 1e-6);
        assert!(rows[0].concurrent_violates && rows[0].tdma_violates);
        // paper row 2: 10 kbps -> 3.2 s, 1,600 s†, 32,000 s†
        assert!((rows[1].upload_per_round_s - 3.2).abs() < 1e-9);
        assert!((rows[1].concurrent_total_s - 1_600.0).abs() < 1e-6);
        assert!((rows[1].tdma_total_s - 32_000.0).abs() < 1e-6);
        assert!(rows[1].concurrent_violates && rows[1].tdma_violates);
        // paper row 3: 50 kbps -> 0.64 s, 320 s (ok), 6,400 s†
        assert!((rows[2].upload_per_round_s - 0.64).abs() < 1e-9);
        assert!((rows[2].concurrent_total_s - 320.0).abs() < 1e-6);
        assert!(!rows[2].concurrent_violates);
        assert!(rows[2].tdma_violates);
        // paper row 4: 100 kbps -> 0.32 s, 160 s (ok), 3,200 s†
        assert!((rows[3].upload_per_round_s - 0.32).abs() < 1e-9);
        assert!((rows[3].concurrent_total_s - 160.0).abs() < 1e-6);
        assert!(!rows[3].concurrent_violates);
        assert!(rows[3].tdma_violates);
    }

    #[test]
    fn fedscalar_never_violates() {
        // FedScalar's 64-bit payload fits the budget at EVERY Table-I
        // operating point — the paper's §I argument.
        for r in table1_rows_fedscalar() {
            assert!(!r.concurrent_violates, "{r:?}");
            assert!(!r.tdma_violates, "{r:?}");
            // worst case: 1 kbps TDMA = 64/1000 * 20 * 500 = 640 s < 1200 s
        }
        let worst = &table1_rows_fedscalar()[0];
        assert!((worst.tdma_total_s - 640.0).abs() < 1e-9);
    }

    #[test]
    fn method_rows_use_strategy_accounting() {
        use crate::algo::Method;
        use crate::rng::VDistribution;
        // the generic path reproduces both hand-built tables exactly...
        assert_eq!(table1_rows_for_method(&Method::fedavg()), table1_rows());
        assert_eq!(
            table1_rows_for_method(&Method::fedscalar(VDistribution::Rademacher, 1)),
            table1_rows_fedscalar()
        );
        // ...and ranks the compression ladder: fedscalar < signsgd < qsgd < fedavg
        let upload = |m: &Method| table1_rows_for_method(m)[0].upload_per_round_s;
        let fs = upload(&Method::fedscalar(VDistribution::Rademacher, 1));
        let sg = upload(&Method::signsgd());
        let q8 = upload(&Method::qsgd(8));
        let fa = upload(&Method::fedavg());
        assert!(fs < sg && sg < q8 && q8 < fa, "{fs} {sg} {q8} {fa}");
    }

    #[test]
    fn render_contains_daggers() {
        let s = render(&table1_rows(), "Table I");
        assert!(s.contains("†"));
        assert!(s.contains("1 kbps"));
        assert!(s.contains("TDMA"));
    }
}

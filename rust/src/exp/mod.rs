//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section. Each bench target and CLI subcommand is a thin
//! wrapper over these functions (see DESIGN.md section 3 for the index).

// Doc debt: this subsystem predates the crate-level `missing_docs`
// warning (added with the daemon PR, which held coordinator/, runlog/,
// telemetry/, and daemon/ to it). Public items below still need doc
// comments; remove this allow once they have them.
#![allow(missing_docs)]

pub mod bench_support;
pub mod figures;
pub mod table1;

pub use figures::{run_figure_suite, FigureSuite, SuiteOptions};
pub use table1::{table1_rows, Table1Row};

//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section. Each bench target and CLI subcommand is a thin
//! wrapper over these functions (see DESIGN.md section 3 for the index).

pub mod bench_support;
pub mod figures;
pub mod table1;

pub use figures::{run_figure_suite, FigureSuite, SuiteOptions};
pub use table1::{table1_rows, Table1Row};

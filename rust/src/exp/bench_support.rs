//! Shared plumbing for the `rust/benches/*` targets (cargo bench runs them
//! with `harness = false`).
//!
//! Environment knobs so `cargo bench` stays tractable while the full paper
//! configuration remains one env var away:
//!   FEDSCALAR_BENCH_ROUNDS  (default 400;  paper: 1500)
//!   FEDSCALAR_BENCH_RUNS    (default 3;    paper: 10)
//!   FEDSCALAR_BENCH_BACKEND (default pure-rust; xla = PJRT artifacts)
//!   FEDSCALAR_BENCH_FULL=1  shorthand for rounds=1500 runs=10

use crate::config::{DataSource, ExperimentConfig};
use crate::error::Result;
use crate::exp::figures::{run_figure_suite, BackendKind, FigureSuite, SuiteOptions};
use std::path::PathBuf;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn bench_rounds() -> usize {
    if std::env::var("FEDSCALAR_BENCH_FULL").is_ok() {
        return 1500;
    }
    env_usize("FEDSCALAR_BENCH_ROUNDS", 600)
}

pub fn bench_runs() -> usize {
    if std::env::var("FEDSCALAR_BENCH_FULL").is_ok() {
        return 10;
    }
    env_usize("FEDSCALAR_BENCH_RUNS", 3)
}

pub fn bench_backend() -> BackendKind {
    std::env::var("FEDSCALAR_BENCH_BACKEND")
        .ok()
        .and_then(|s| BackendKind::parse(&s))
        .unwrap_or(BackendKind::PureRust)
}

/// The §III experiment at bench scale. Uses the artifact CSVs when
/// available (so Rust and JAX consume identical data), synthetic otherwise.
pub fn bench_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_section_iii();
    cfg.fed.rounds = bench_rounds();
    cfg.fed.eval_every = (cfg.fed.rounds / 30).max(1);
    if !PathBuf::from("artifacts/manifest.txt").exists() {
        cfg.data = DataSource::Synthetic;
    }
    cfg
}

/// Run (once) the four-method suite that Figs 2-6 all project from.
pub fn run_paper_suite(out_subdir: &str) -> Result<FigureSuite> {
    let cfg = bench_config();
    let opts = SuiteOptions {
        runs: bench_runs(),
        backend: bench_backend(),
        out_dir: Some(PathBuf::from("results").join(out_subdir)),
        parallel: true,
        ..Default::default()
    };
    println!(
        "suite: K={} runs={} backend={} data={:?} (set FEDSCALAR_BENCH_FULL=1 for the paper's 1500x10)",
        cfg.fed.rounds,
        opts.runs,
        opts.backend.name(),
        cfg.data
    );
    run_figure_suite(&cfg, &opts)
}

/// Pretty-print one x/y series per method at a set of grid points.
pub fn print_series(
    title: &str,
    suite: &FigureSuite,
    x_label: &str,
    x_of: impl Fn(&crate::metrics::RoundRecord) -> f64,
    y_of: impl Fn(&crate::metrics::RoundRecord) -> f64,
    points: usize,
) {
    println!("\n=== {title} ===");
    for (method, h) in &suite.per_method {
        println!("-- {}", method.name());
        let n = h.records.len();
        let step = (n / points.max(1)).max(1);
        println!("   {:<16} {:>12}", x_label, "value");
        for (i, r) in h.records.iter().enumerate() {
            if i % step == 0 || i + 1 == n {
                println!("   {:<16.6e} {:>12.4}", x_of(r), y_of(r));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        assert!(bench_rounds() >= 1);
        assert!(bench_runs() >= 1);
        let cfg = bench_config();
        cfg.validate().unwrap();
    }
}

//! Per-round metrics: records, recorder, multi-run aggregation, CSV export.
//!
//! Every figure of the paper is a projection of these records:
//! Fig 2 = (round, train_loss), Fig 3 = (round, test_acc),
//! Fig 4 = (cum_bits, test_acc), Fig 5 = (cum_sim_time, test_acc),
//! Fig 6 = (cum_energy, test_acc).

// Doc debt: this subsystem predates the crate-level `missing_docs`
// warning (added with the daemon PR, which held coordinator/, runlog/,
// telemetry/, and daemon/ to it). Public items below still need doc
// comments; remove this allow once they have them.
#![allow(missing_docs)]

use crate::error::Result;
use crate::util::csv::CsvWriter;
use crate::util::stats;
use std::path::Path;

/// One evaluated round of one run.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean client-reported local loss this round (Fig 2 series).
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// Cumulative uplink bits across all agents since round 0 (Fig 4 x).
    pub cum_bits: f64,
    /// Cumulative downlink (broadcast) bits across all selected agents —
    /// the first-class downlink cost of Zheng et al. (PAPERS.md), charged
    /// via `Strategy::downlink_bits`.
    pub cum_downlink_bits: f64,
    /// Cumulative simulated wall-clock seconds, eq. 12 (Fig 5 x).
    pub cum_sim_seconds: f64,
    /// Cumulative transmit energy in joules, eq. 13 (Fig 6 x).
    pub cum_energy_joules: f64,
    /// Real (host) milliseconds spent on this round — perf diagnostics.
    pub host_ms: f64,
}

/// The record stream of one run.
#[derive(Debug, Clone, Default)]
pub struct RunHistory {
    pub method: String,
    pub records: Vec<RoundRecord>,
}

impl RunHistory {
    pub fn new(method: impl Into<String>) -> Self {
        RunHistory {
            method: method.into(),
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.records.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    pub fn final_train_loss(&self) -> f64 {
        self.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN)
    }

    pub fn series(&self, f: impl Fn(&RoundRecord) -> f64) -> Vec<f64> {
        self.records.iter().map(f).collect()
    }

    /// Accuracy at a cumulative-bits budget (Fig 4 readout).
    pub fn acc_at_bits(&self, budget: f64) -> Option<f64> {
        stats::value_at(
            &self.series(|r| r.cum_bits),
            &self.series(|r| r.test_acc),
            budget,
        )
    }

    /// Accuracy at a total-communication budget: uplink + downlink bits
    /// (the symmetric cost model of Zheng et al.).
    pub fn acc_at_total_bits(&self, budget: f64) -> Option<f64> {
        stats::value_at(
            &self.series(|r| r.cum_bits + r.cum_downlink_bits),
            &self.series(|r| r.test_acc),
            budget,
        )
    }

    /// Accuracy at a simulated-time budget (Fig 5 readout).
    pub fn acc_at_seconds(&self, budget: f64) -> Option<f64> {
        stats::value_at(
            &self.series(|r| r.cum_sim_seconds),
            &self.series(|r| r.test_acc),
            budget,
        )
    }

    /// Accuracy at an energy budget (Fig 6 readout).
    pub fn acc_at_joules(&self, budget: f64) -> Option<f64> {
        stats::value_at(
            &self.series(|r| r.cum_energy_joules),
            &self.series(|r| r.test_acc),
            budget,
        )
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "round",
                "train_loss",
                "test_loss",
                "test_acc",
                "cum_bits",
                "cum_downlink_bits",
                "cum_sim_seconds",
                "cum_energy_joules",
                "host_ms",
            ],
        )?;
        for r in &self.records {
            w.row(&[
                r.round as f64,
                r.train_loss,
                r.test_loss,
                r.test_acc,
                r.cum_bits,
                r.cum_downlink_bits,
                r.cum_sim_seconds,
                r.cum_energy_joules,
                r.host_ms,
            ])?;
        }
        w.flush()
    }
}

/// Bit-equality that treats NaN as equal to NaN. Applied ONLY to
/// `train_loss` — the one field with a legitimate NaN (a round where no
/// client was reachable); every other metric keeps strict equality so a
/// bug that NaNs a counter in both engines still fails the comparison.
fn feq(a: f64, b: f64) -> bool {
    a == b || (a.is_nan() && b.is_nan())
}

impl RoundRecord {
    /// Equality on the *deterministic* metrics — everything except
    /// `host_ms`, which measures real wall time and differs run to run.
    pub fn same_metrics(&self, other: &RoundRecord) -> bool {
        self.round == other.round
            && feq(self.train_loss, other.train_loss)
            && self.test_loss == other.test_loss
            && self.test_acc == other.test_acc
            && self.cum_bits == other.cum_bits
            && self.cum_downlink_bits == other.cum_downlink_bits
            && self.cum_sim_seconds == other.cum_sim_seconds
            && self.cum_energy_joules == other.cum_energy_joules
    }
}

/// True when both histories agree on all deterministic metrics.
pub fn same_histories(a: &RunHistory, b: &RunHistory) -> bool {
    a.method == b.method
        && a.records.len() == b.records.len()
        && a.records
            .iter()
            .zip(&b.records)
            .all(|(x, y)| x.same_metrics(y))
}

/// Element-wise mean across runs of the same method (round grids must
/// match), the "averaged over 10 runs" aggregation of the paper.
pub fn average_runs(runs: &[RunHistory]) -> RunHistory {
    assert!(!runs.is_empty());
    let n = runs[0].records.len();
    assert!(
        runs.iter().all(|r| r.records.len() == n),
        "runs have mismatched round grids"
    );
    let mut out = RunHistory::new(runs[0].method.clone());
    for i in 0..n {
        let pick = |f: &dyn Fn(&RoundRecord) -> f64| -> f64 {
            stats::mean(&runs.iter().map(|r| f(&r.records[i])).collect::<Vec<_>>())
        };
        out.push(RoundRecord {
            round: runs[0].records[i].round,
            train_loss: pick(&|r| r.train_loss),
            test_loss: pick(&|r| r.test_loss),
            test_acc: pick(&|r| r.test_acc),
            cum_bits: pick(&|r| r.cum_bits),
            cum_downlink_bits: pick(&|r| r.cum_downlink_bits),
            cum_sim_seconds: pick(&|r| r.cum_sim_seconds),
            cum_energy_joules: pick(&|r| r.cum_energy_joules),
            host_ms: pick(&|r| r.host_ms),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, bits: f64, secs: f64, joules: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            test_loss: 0.5,
            test_acc: acc,
            cum_bits: bits,
            cum_downlink_bits: 10.0 * bits,
            cum_sim_seconds: secs,
            cum_energy_joules: joules,
            host_ms: 1.0,
        }
    }

    fn history() -> RunHistory {
        let mut h = RunHistory::new("fedscalar-rademacher");
        h.push(rec(0, 0.1, 100.0, 1.0, 0.5));
        h.push(rec(10, 0.5, 200.0, 2.0, 1.0));
        h.push(rec(20, 0.9, 300.0, 3.0, 1.5));
        h
    }

    #[test]
    fn budget_readouts() {
        let h = history();
        assert_eq!(h.acc_at_bits(250.0), Some(0.5));
        assert_eq!(h.acc_at_bits(50.0), None);
        assert_eq!(h.acc_at_seconds(3.0), Some(0.9));
        assert_eq!(h.acc_at_joules(1.2), Some(0.5));
        // total = uplink + downlink = 11x the uplink series here
        assert_eq!(h.acc_at_total_bits(2500.0), Some(0.5));
        assert_eq!(h.acc_at_total_bits(1000.0), None);
        assert_eq!(h.final_accuracy(), 0.9);
    }

    #[test]
    fn nan_rounds_compare_equal_across_engines() {
        // an all-dropped round records NaN train loss in BOTH engines;
        // history comparison must not treat that as divergence
        let mut a = rec(3, 0.5, 100.0, 1.0, 0.5);
        let mut b = rec(3, 0.5, 100.0, 1.0, 0.5);
        a.train_loss = f64::NAN;
        b.train_loss = f64::NAN;
        assert!(a.same_metrics(&b));
        b.train_loss = 0.2;
        assert!(!a.same_metrics(&b));
    }

    #[test]
    fn averaging_runs() {
        let mut a = history();
        let mut b = history();
        a.records[2].test_acc = 0.8;
        b.records[2].test_acc = 1.0;
        let avg = average_runs(&[a, b]);
        assert_eq!(avg.records.len(), 3);
        assert!((avg.records[2].test_acc - 0.9).abs() < 1e-12);
        assert_eq!(avg.method, "fedscalar-rademacher");
    }

    #[test]
    #[should_panic(expected = "mismatched")]
    fn ragged_runs_panic() {
        let a = history();
        let mut b = history();
        b.records.pop();
        average_runs(&[a, b]);
    }

    #[test]
    fn csv_roundtrip_linecount() {
        let h = history();
        let p = std::env::temp_dir().join(format!("fedscalar_hist_{}.csv", std::process::id()));
        h.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 4); // header + 3 rows
        assert!(text.lines().next().unwrap().starts_with("round,train_loss"));
        std::fs::remove_file(p).ok();
    }
}

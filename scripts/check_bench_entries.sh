#!/usr/bin/env bash
# Fail if a hotpath bench JSON is missing any expected entry name —
# catches benches that silently stopped running (renamed, gated away,
# early-exited) before a hole appears in the perf trajectory.
#
#   scripts/check_bench_entries.sh [BENCH.json] [EXPECTED.txt]
#
# Defaults check the quick-mode file verify.sh / CI produce.
set -euo pipefail

json="${1:-rust/BENCH_hotpath.quick.json}"
expected="${2:-rust/benches/hotpath_expected.txt}"

python3 - "$json" "$expected" <<'PY'
import json
import sys

json_path, expected_path = sys.argv[1], sys.argv[2]
with open(json_path) as f:
    entries = json.load(f)
with open(expected_path) as f:
    expected = [l.strip() for l in f if l.strip() and not l.lstrip().startswith("#")]

missing = [name for name in expected if name not in entries]
if missing:
    print(f"{json_path}: {len(missing)} expected bench entr(ies) missing:")
    for name in missing:
        print(f"  - {name}")
    sys.exit(1)
print(f"{json_path}: all {len(expected)} expected entries present ({len(entries)} total)")
PY

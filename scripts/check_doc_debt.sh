#!/usr/bin/env bash
# Fail if the number of `allow(missing_docs)` gates under rust/src grows
# past the recorded baseline — doc debt is allowed to shrink (update the
# baseline when it does), never to creep back in. The crate-level
# `missing_docs` warning plus `cargo doc -D warnings` holds every
# ungated module to full API docs; this script holds the set of gated
# modules itself.
#
#   scripts/check_doc_debt.sh [SRC_DIR] [BASELINE]
set -euo pipefail

src="${1:-rust/src}"
baseline="${2:-10}"

python3 - "$src" "$baseline" <<'PY'
import pathlib
import sys

src, baseline = pathlib.Path(sys.argv[1]), int(sys.argv[2])
gated = sorted(
    str(p)
    for p in src.rglob("*.rs")
    if "allow(missing_docs)" in p.read_text()
)
if len(gated) > baseline:
    print(
        f"{src}: {len(gated)} allow(missing_docs) gate(s), "
        f"baseline is {baseline} — new public APIs must ship documented:"
    )
    for p in gated:
        print(f"  - {p}")
    sys.exit(1)
if len(gated) < baseline:
    print(
        f"{src}: {len(gated)} gate(s) < baseline {baseline} — "
        f"debt shrank; lower the baseline in scripts/check_doc_debt.sh "
        f"and .github/workflows/ci.yml to lock it in"
    )
print(f"{src}: {len(gated)} allow(missing_docs) gate(s) (baseline {baseline})")
PY

#!/usr/bin/env bash
# Fail if the telemetry snapshot JSON is missing any expected metric
# name — catches metrics that silently dropped out of the exposition
# catalog (renamed, gated away, never registered) before a dashboard or
# the status surface goes dark.
#
#   scripts/check_metric_names.sh [TELEMETRY.json] [EXPECTED.txt]
#
# Defaults check the quick-mode snapshot verify.sh / CI produce. A
# listed name passes if it is an exact key or a labelled family: some
# key starting with `name{`.
set -euo pipefail

json="${1:-rust/TELEMETRY_hotpath.quick.json}"
expected="${2:-rust/telemetry_expected.txt}"

python3 - "$json" "$expected" <<'PY'
import json
import sys

json_path, expected_path = sys.argv[1], sys.argv[2]
with open(json_path) as f:
    keys = set(json.load(f))
with open(expected_path) as f:
    expected = [l.strip() for l in f if l.strip() and not l.lstrip().startswith("#")]

def present(name):
    if name in keys:
        return True
    prefix = name + "{"
    return any(k.startswith(prefix) for k in keys)

missing = [name for name in expected if not present(name)]
if missing:
    print(f"{json_path}: {len(missing)} expected metric name(s) missing:")
    for name in missing:
        print(f"  - {name}")
    sys.exit(1)
print(f"{json_path}: all {len(expected)} expected metric names present ({len(keys)} keys)")
PY

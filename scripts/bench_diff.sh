#!/usr/bin/env bash
# Diff two hotpath trajectory files (flat {"name": ns_per_iter} JSON, as
# written by `cargo bench --bench hotpath`) and print a per-entry
# regression table.
#
#   scripts/bench_diff.sh OLD.json NEW.json [--fail-above PCT]
#
# Entries present in only one file are listed separately. With
# --fail-above PCT the script exits 1 if any shared entry regressed by
# more than PCT percent (useful as a soft perf gate on the full-budget
# trajectory; quick-mode numbers are too noisy to gate on).
set -euo pipefail

if [ "$#" -lt 2 ]; then
    echo "usage: $0 OLD.json NEW.json [--fail-above PCT]" >&2
    exit 2
fi

python3 - "$@" <<'PY'
import json
import sys

old_path, new_path = sys.argv[1], sys.argv[2]
fail_above = None
if len(sys.argv) > 3:
    if sys.argv[3] != "--fail-above" or len(sys.argv) < 5:
        sys.exit(f"usage: bench_diff.sh OLD.json NEW.json [--fail-above PCT]")
    fail_above = float(sys.argv[4])

with open(old_path) as f:
    old = json.load(f)
with open(new_path) as f:
    new = json.load(f)

shared = [n for n in new if n in old]
width = max((len(n) for n in shared), default=4)
print(f"{'entry':<{width}}  {'old ns/iter':>14}  {'new ns/iter':>14}  {'delta':>9}")
print("-" * (width + 43))
worst = []
for name in shared:
    o, n = old[name], new[name]
    delta = (n - o) / o * 100.0 if o else float("inf")
    mark = ""
    if delta >= 10.0:
        mark = "  REGRESSED"
    elif delta <= -10.0:
        mark = "  improved"
    print(f"{name:<{width}}  {o:>14,.1f}  {n:>14,.1f}  {delta:>+8.1f}%{mark}")
    if fail_above is not None and delta > fail_above:
        worst.append((name, delta))

only_old = [n for n in old if n not in new]
only_new = [n for n in new if n not in old]
if only_old:
    print(f"\nonly in {old_path}:")
    for n in only_old:
        print(f"  - {n}")
if only_new:
    print(f"\nonly in {new_path}:")
    for n in only_new:
        print(f"  + {n}")

if worst:
    print(f"\n{len(worst)} entr(ies) regressed beyond {fail_above:.1f}%:")
    for name, delta in sorted(worst, key=lambda x: -x[1]):
        print(f"  {name}: {delta:+.1f}%")
    sys.exit(1)
PY

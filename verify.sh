#!/usr/bin/env bash
# Tier-1 verification in one command (see ROADMAP.md):
#   ./verify.sh          build + tests + fmt check + quick hotpath bench
#   ./verify.sh --fast   skip the release build (debug tests only)
#
# The hotpath bench runs in quick mode (FEDSCALAR_BENCH_QUICK=1) and
# leaves rust/BENCH_hotpath.quick.json (quick budgets are noisy, so they
# get their own file; the cross-PR trajectory file BENCH_hotpath.json is
# only written by a full `cargo bench --bench hotpath`).

set -uo pipefail
cd "$(dirname "$0")/rust"

fail=0
step() {
    echo
    echo "==> $*"
    if ! "$@"; then
        echo "FAILED: $*"
        fail=1
    fi
}

if [ "${1:-}" != "--fast" ]; then
    step cargo build --release
fi
step cargo test -q

# chaos smoke: a drop/corrupt/crash-heavy distributed run must complete
# every round and exit 0 (skipped in --fast mode: wants the release
# binary the build step above produced)
if [ "${1:-}" != "--fast" ]; then
    step cargo run --release --quiet -- train --engine distributed \
        --data synthetic --rounds 6 --agents 4 --eval-every 3 \
        --fault-seed 42 --fault-drop 0.15 --fault-corrupt 0.1 \
        --fault-duplicate 0.1 --fault-crash 0.2 --fault-respawn \
        --out /tmp/fedscalar_chaos_smoke.csv
fi

# fmt is advisory when rustfmt isn't installed in the container
if cargo fmt --version >/dev/null 2>&1; then
    step cargo fmt --check
else
    echo "(cargo fmt unavailable — skipping format check)"
fi

# lints gate when clippy is installed (build containers without a
# toolchain skip the whole script anyway; see .claude/skills/verify)
if cargo clippy --version >/dev/null 2>&1; then
    step cargo clippy --all-targets -- -D warnings
else
    echo "(cargo clippy unavailable — skipping lint check)"
fi

echo
echo "==> FEDSCALAR_BENCH_QUICK=1 cargo bench --bench hotpath"
if ! FEDSCALAR_BENCH_QUICK=1 cargo bench --bench hotpath; then
    echo "FAILED: hotpath bench"
    fail=1
fi

echo
if [ "$fail" -eq 0 ]; then
    echo "verify: ALL GREEN"
else
    echo "verify: FAILURES (see above)"
fi
exit "$fail"

"""FedScalar client/server stages: seed round-trip, unbiasedness, variance.

These tests validate the paper's core claims at the JAX layer:
  - Lemma 2.1  E[<v, g> v] = g       (unbiased reconstruction)
  - Lemma 2.2  E[||<v, g> v||^2] <= (d+4) ||g||^2   (Gaussian second moment)
  - Prop. 2.1  Var_Gauss - Var_Rademacher = (2/N^2) sum ||delta||^2  (per-coord)
  - the seed round-trip: client and server regenerate bit-identical v.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import fed, model


def _params_and_batches(seed=0, s=2, b=8):
    rng = np.random.default_rng(seed)
    p = model.init_params(seed)
    xb = jnp.asarray(rng.uniform(0, 1, size=(s, b, model.INPUT_DIM)).astype(np.float32))
    yb = jnp.asarray(rng.integers(0, 10, size=(s, b)).astype(np.int32))
    return p, xb, yb


# --- seed round-trip ----------------------------------------------------------


@pytest.mark.parametrize("dist", fed.DISTRIBUTIONS)
def test_seed_roundtrip_bit_identical(dist):
    """sample_v in a 'client' jit and a 'server' vmapped jit agree exactly."""
    seeds = jnp.asarray([0, 1, 42, 2**31, 2**32 - 1], jnp.uint32)
    client_side = jax.jit(lambda s: fed.sample_v(s, dist))
    server_side = jax.jit(jax.vmap(lambda s: fed.sample_v(s, dist)))
    vs_server = np.asarray(server_side(seeds))
    for i, s in enumerate(np.asarray(seeds)):
        v_client = np.asarray(client_side(jnp.uint32(s)))
        np.testing.assert_array_equal(v_client, vs_server[i])


def test_distinct_seeds_distinct_vectors():
    a = np.asarray(fed.sample_v(jnp.uint32(1), "normal"))
    b = np.asarray(fed.sample_v(jnp.uint32(2), "normal"))
    assert not np.array_equal(a, b)


def test_rademacher_is_pm_one():
    v = np.asarray(fed.sample_v(jnp.uint32(7), "rademacher"))
    assert set(np.unique(v)).issubset({-1.0, 1.0})


def test_sample_v_rejects_unknown_dist():
    with pytest.raises(ValueError):
        fed.sample_v(jnp.uint32(0), "uniform")


# --- unbiasedness (Lemma 2.1) and second moment (Lemma 2.2) -------------------


@pytest.mark.parametrize("dist", fed.DISTRIBUTIONS)
def test_reconstruction_unbiased_monte_carlo(dist):
    """E[<delta, v> v] ~= delta across many seeds."""
    d = 64
    rng = np.random.default_rng(0)
    delta = jnp.asarray(rng.normal(size=d).astype(np.float32))
    m = 4000
    fn = jax.jit(jax.vmap(lambda s: fed.sample_v(s, dist, dim=d)))
    vs = fn(jnp.arange(m, dtype=jnp.uint32))
    est = np.asarray(jnp.mean((vs @ delta)[:, None] * vs, axis=0))
    err = np.linalg.norm(est - np.asarray(delta)) / np.linalg.norm(np.asarray(delta))
    # MC error ~ sqrt(d/m); generous factor
    assert err < 0.35, err


def test_gaussian_second_moment_bound():
    """E[||<v,g>v||^2] <= (d+4)||g||^2 (Lemma 2.2), checked by Monte Carlo."""
    d = 32
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=d).astype(np.float32))
    m = 6000
    vs = jax.vmap(lambda s: fed.sample_v(s, "normal", dim=d))(jnp.arange(m, dtype=jnp.uint32))
    sq = np.asarray(jnp.sum(((vs @ g)[:, None] * vs) ** 2, axis=1))
    bound = (d + 4) * float(jnp.sum(g * g))
    assert sq.mean() <= bound * 1.05  # 5% MC slack


def test_rademacher_projection_variance_below_gaussian():
    """Empirical Var[r v] per coordinate: Rademacher < Gaussian (Prop 2.1).

    Exact second moments (Isserlis / direct expansion), N = 1:
      Gaussian:   E[x_i^2] = ||delta||^2 + 2 delta_i^2
      Rademacher: E[x_i^2] = ||delta||^2
    so the per-coordinate mean trace gap is exactly 2 ||delta||^2 / d —
    Proposition 2.1 with N = 1.
    """
    d = 32
    rng = np.random.default_rng(2)
    delta = jnp.asarray(rng.normal(size=d).astype(np.float32))
    m = 40_000
    seeds = jnp.arange(m, dtype=jnp.uint32)

    def recon_e2(dist):
        vs = jax.vmap(lambda s: fed.sample_v(s, dist, dim=d))(seeds)
        recon = (vs @ delta)[:, None] * vs  # [m, d]
        return float(jnp.mean(recon**2))

    eg = recon_e2("normal")
    er = recon_e2("rademacher")
    assert er < eg, (er, eg)
    gap = eg - er
    want = 2.0 * float(jnp.sum(delta * delta)) / d
    assert abs(gap - want) / want < 0.5, (gap, want)
    # absolute levels match the exact formulas too
    dsq = float(jnp.sum(delta * delta))
    assert abs(er - dsq) / dsq < 0.05, (er, dsq)
    want_g = dsq * (1.0 + 2.0 / d)
    assert abs(eg - want_g) / want_g < 0.05, (eg, want_g)


# --- client/server composition ------------------------------------------------


@pytest.mark.parametrize("dist", fed.DISTRIBUTIONS)
def test_client_fedscalar_equals_manual_composition(dist):
    p, xb, yb = _params_and_batches(seed=3)
    seed = jnp.uint32(123)
    alpha = jnp.float32(0.01)
    r, loss, dsq = fed.client_fedscalar(p, xb, yb, seed, alpha, dist=dist)
    delta, loss2 = model.local_sgd(p, xb, yb, alpha)
    v = fed.sample_v(seed, dist)
    np.testing.assert_allclose(float(r), float(jnp.vdot(delta, v)), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)
    np.testing.assert_allclose(float(dsq), float(jnp.sum(delta * delta)), rtol=1e-5)


@pytest.mark.parametrize("dist", fed.DISTRIBUTIONS)
def test_server_reconstruct_matches_manual(dist):
    n = 5
    rng = np.random.default_rng(4)
    rs = jnp.asarray(rng.normal(size=n).astype(np.float32))
    seeds = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    ghat = fed.server_reconstruct(rs, seeds, dist=dist)
    want = jnp.zeros((model.PARAM_DIM,), jnp.float32)
    for i in range(n):
        want = want + rs[i] * fed.sample_v(seeds[i], dist)
    want = want / n
    np.testing.assert_allclose(np.asarray(ghat), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_single_round_descends_in_expectation():
    """The decoded update r*v, averaged over many seeds, points along delta.

    cos(ghat, delta) concentrates around 1/sqrt(1 + d/m): for d = 1990 and
    m = 8192 that is ~0.90; we assert a conservative 0.7. (local_sgd is run
    once; the seed average only exercises the encode/decode pair, whose
    composition with local_sgd is covered above.)
    """
    p, xb, yb = _params_and_batches(seed=5, s=3, b=16)
    alpha = jnp.float32(0.02)
    delta, _ = model.local_sgd(p, xb, yb, alpha)
    m = 8192
    seeds = jnp.arange(m, dtype=jnp.uint32)

    def one(seed):
        v = fed.sample_v(seed, "rademacher")
        return jnp.vdot(delta, v) * v

    ghat = jnp.mean(jax.vmap(one)(seeds), axis=0)
    cos = float(jnp.vdot(ghat, delta) / (jnp.linalg.norm(ghat) * jnp.linalg.norm(delta)))
    assert cos > 0.7, cos


@pytest.mark.parametrize("dist", fed.DISTRIBUTIONS)
def test_client_batch_matches_per_client_loop(dist):
    """The vmapped fast-path artifact computes exactly the per-client stage."""
    n = 3
    rng = np.random.default_rng(8)
    p = model.init_params(1)
    xbs = jnp.asarray(rng.uniform(0, 1, size=(n, 2, 8, model.INPUT_DIM)).astype(np.float32))
    ybs = jnp.asarray(rng.integers(0, 10, size=(n, 2, 8)).astype(np.int32))
    seeds = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    alpha = jnp.float32(0.01)
    rs_b, losses_b, dsqs_b = fed.client_fedscalar_batch(p, xbs, ybs, seeds, alpha, dist=dist)
    for i in range(n):
        r, loss, dsq = fed.client_fedscalar(p, xbs[i], ybs[i], seeds[i], alpha, dist=dist)
        np.testing.assert_allclose(float(rs_b[i]), float(r), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(losses_b[i]), float(loss), rtol=1e-5)
        np.testing.assert_allclose(float(dsqs_b[i]), float(dsq), rtol=1e-4)


def test_client_delta_is_local_sgd():
    p, xb, yb = _params_and_batches(seed=6)
    d1, l1 = fed.client_delta(p, xb, yb, jnp.float32(0.01))
    d2, l2 = model.local_sgd(p, xb, yb, jnp.float32(0.01))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    assert float(l1) == float(l2)

"""L1 kernels vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes (and the projection/reconstruct block sizes) and
asserts allclose against ref.py for every kernel. Anything that disagrees
here would silently corrupt every federated round downstream.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import fused_linear
from compile.kernels.projection import projection, pad_to_block
from compile.kernels.reconstruct import reconstruct

jax.config.update("jax_enable_x64", False)


def _rng(seed):
    return np.random.default_rng(seed)


# --- projection --------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=32),
    block=st.sampled_from([8, 64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_projection_matches_ref(blocks, block, seed):
    rng = _rng(seed)
    d = blocks * block
    delta = rng.normal(size=d).astype(np.float32)
    v = rng.normal(size=d).astype(np.float32)
    got = projection(jnp.asarray(delta), jnp.asarray(v), block=block)
    want = ref.projection_ref(jnp.asarray(delta), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-4)


def test_projection_zero_vector():
    d = 256
    z = jnp.zeros((d,), jnp.float32)
    v = jnp.ones((d,), jnp.float32)
    assert float(projection(z, v)) == 0.0


def test_projection_orthogonal():
    # e_0 . e_1 = 0, e_0 . e_0 = 1
    d = 128
    e0 = jnp.zeros((d,)).at[0].set(1.0)
    e1 = jnp.zeros((d,)).at[1].set(1.0)
    assert float(projection(e0, e1)) == 0.0
    assert float(projection(e0, e0)) == 1.0


def test_projection_rejects_unpadded():
    with pytest.raises(AssertionError):
        projection(jnp.zeros((100,)), jnp.zeros((100,)), block=128)


def test_pad_to_block_1d_and_2d():
    x = jnp.ones((5,))
    p = pad_to_block(x, 8)
    assert p.shape == (8,)
    assert float(jnp.sum(p)) == 5.0
    x2 = jnp.ones((3, 5))
    p2 = pad_to_block(x2, 8)
    assert p2.shape == (3, 8)
    # already aligned: returned unchanged
    assert pad_to_block(jnp.ones((16,)), 8).shape == (16,)


def test_projection_padding_is_transparent():
    rng = _rng(7)
    d = 1990
    delta = jnp.asarray(rng.normal(size=d).astype(np.float32))
    v = jnp.asarray(rng.normal(size=d).astype(np.float32))
    got = projection(pad_to_block(delta), pad_to_block(v))
    np.testing.assert_allclose(got, ref.projection_ref(delta, v), rtol=2e-5, atol=1e-4)


# --- reconstruct --------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=24),
    blocks=st.integers(min_value=1, max_value=8),
    block=st.sampled_from([8, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_reconstruct_matches_ref(n, blocks, block, seed):
    rng = _rng(seed)
    d = blocks * block
    r = rng.normal(size=n).astype(np.float32)
    vs = rng.normal(size=(n, d)).astype(np.float32)
    got = reconstruct(jnp.asarray(r), jnp.asarray(vs), block=block)
    want = ref.reconstruct_ref(jnp.asarray(r), jnp.asarray(vs))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-4)


def test_reconstruct_single_agent_is_scale():
    rng = _rng(3)
    v = rng.normal(size=(1, 256)).astype(np.float32)
    r = np.array([2.5], np.float32)
    got = np.asarray(reconstruct(jnp.asarray(r), jnp.asarray(v)))
    np.testing.assert_allclose(got, 2.5 * v[0], rtol=1e-6)


def test_reconstruct_linearity():
    """reconstruct(a+b, V) == reconstruct(a, V) + reconstruct(b, V)."""
    rng = _rng(11)
    n, d = 6, 384
    a = rng.normal(size=n).astype(np.float32)
    b = rng.normal(size=n).astype(np.float32)
    vs = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    lhs = reconstruct(jnp.asarray(a + b), vs)
    rhs = reconstruct(jnp.asarray(a), vs) + reconstruct(jnp.asarray(b), vs)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


# --- fused linear --------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=48),
    d_in=st.integers(min_value=1, max_value=64),
    d_out=st.integers(min_value=1, max_value=32),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_linear_matches_ref(batch, d_in, d_out, relu, seed):
    rng = _rng(seed)
    x = rng.normal(size=(batch, d_in)).astype(np.float32)
    w = rng.normal(size=(d_in, d_out)).astype(np.float32)
    b = rng.normal(size=d_out).astype(np.float32)
    got = fused_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), relu=relu)
    oracle = ref.linear_relu_ref if relu else ref.linear_ref
    want = oracle(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_fused_linear_relu_clamps():
    x = jnp.asarray([[-1.0, -2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = np.asarray(fused_linear(x, w, b, relu=True))
    assert (out >= 0).all()
    np.testing.assert_allclose(out, [[0.0, 0.0]])

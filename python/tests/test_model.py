"""L2 model: shapes, flat-layout round-trip, gradients vs a pure-jnp twin."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def _pure_forward(params, x):
    """Reference forward with no Pallas anywhere (autodiffed by jax.grad)."""
    w1, b1, w2, b2, w3, b3 = model.unflatten(params)
    h1 = jnp.maximum(x @ w1 + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
    return h2 @ w3 + b3


def _pure_loss(params, x, y):
    return model.softmax_cross_entropy(_pure_forward(params, x), y)


def _batch(seed, b=8):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, size=(b, model.INPUT_DIM)).astype(np.float32)
    y = rng.integers(0, model.NUM_CLASSES, size=b).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def test_param_dim_is_1990():
    assert model.PARAM_DIM == 1990  # paper: "approximately 2000"


def test_flatten_unflatten_roundtrip():
    p = model.init_params(0)
    assert p.shape == (model.PARAM_DIM,)
    again = model.flatten(model.unflatten(p))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(again))


def test_forward_shape_and_finite():
    p = model.init_params(1)
    x, _ = _batch(0, b=17)
    logits = model.forward(p, x)
    assert logits.shape == (17, model.NUM_CLASSES)
    assert bool(jnp.isfinite(logits).all())


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_forward_matches_pure_jnp(seed):
    p = model.init_params(seed % 5)
    x, _ = _batch(seed)
    np.testing.assert_allclose(
        model.forward(p, x), _pure_forward(p, x), rtol=2e-5, atol=1e-5
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_grad_matches_pure_jnp(seed):
    """custom_vjp through the Pallas layers == jax.grad of the jnp twin."""
    p = model.init_params(seed % 3)
    x, y = _batch(seed)
    g_pallas = model.grad_fn(p, x, y)
    g_pure = jax.grad(_pure_loss)(p, x, y)
    np.testing.assert_allclose(g_pallas, g_pure, rtol=5e-4, atol=1e-5)


def test_grad_numerical_spotcheck():
    """Central-difference check on a few random coordinates."""
    p = model.init_params(2)
    x, y = _batch(42, b=4)
    g = np.asarray(model.grad_fn(p, x, y))
    rng = np.random.default_rng(0)
    eps = 1e-3
    for idx in rng.integers(0, model.PARAM_DIM, size=6):
        e = np.zeros(model.PARAM_DIM, np.float32)
        e[idx] = eps
        hi = float(model.loss_fn(p + e, x, y))
        lo = float(model.loss_fn(p - e, x, y))
        fd = (hi - lo) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-3, (idx, fd, g[idx])


def test_local_sgd_reduces_loss_and_returns_delta():
    p = model.init_params(3)
    rng = np.random.default_rng(1)
    s, b = 5, 32
    xb = jnp.asarray(rng.uniform(0, 1, size=(s, b, model.INPUT_DIM)).astype(np.float32))
    yb = jnp.asarray(rng.integers(0, 10, size=(s, b)).astype(np.int32))
    delta, loss = model.local_sgd(p, xb, yb, 0.05)
    assert delta.shape == (model.PARAM_DIM,)
    assert float(loss) > 0
    # after applying delta, loss on the same batches should not be higher
    before = float(model.loss_fn(p, xb[0], yb[0]))
    after = float(model.loss_fn(p + delta, xb[0], yb[0]))
    assert after < before


def test_local_sgd_zero_lr_is_noop():
    p = model.init_params(4)
    rng = np.random.default_rng(2)
    xb = jnp.asarray(rng.uniform(0, 1, size=(2, 4, model.INPUT_DIM)).astype(np.float32))
    yb = jnp.asarray(rng.integers(0, 10, size=(2, 4)).astype(np.int32))
    delta, _ = model.local_sgd(p, xb, yb, 0.0)
    np.testing.assert_array_equal(np.asarray(delta), np.zeros(model.PARAM_DIM, np.float32))


def test_local_sgd_matches_manual_loop():
    p = model.init_params(5)
    rng = np.random.default_rng(3)
    s, b, alpha = 3, 8, 0.01
    xb = rng.uniform(0, 1, size=(s, b, model.INPUT_DIM)).astype(np.float32)
    yb = rng.integers(0, 10, size=(s, b)).astype(np.int32)
    delta, _ = model.local_sgd(p, jnp.asarray(xb), jnp.asarray(yb), alpha)
    q = p
    for i in range(s):
        q = q - alpha * model.grad_fn(q, jnp.asarray(xb[i]), jnp.asarray(yb[i]))
    np.testing.assert_allclose(np.asarray(p + delta), np.asarray(q), rtol=1e-5, atol=1e-6)


def test_evaluate_perfect_and_chance():
    p = model.init_params(6)
    x, y = _batch(9, b=64)
    loss, acc = model.evaluate(p, x, y)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0.0


def test_init_params_glorot_bounds():
    p = np.asarray(model.init_params(7))
    w1 = p[: 64 * 24]
    limit = (6.0 / (64 + 24)) ** 0.5
    assert (np.abs(w1) <= limit + 1e-6).all()
    # biases are zero
    b1 = p[64 * 24 : 64 * 24 + 24]
    np.testing.assert_array_equal(b1, 0.0)


def test_init_params_deterministic_and_seed_sensitive():
    a = np.asarray(model.init_params(8))
    b = np.asarray(model.init_params(8))
    c = np.asarray(model.init_params(9))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)

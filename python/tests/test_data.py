"""Synthetic Digits substrate: determinism, class balance, learnability."""

import os

import numpy as np

from compile import data as data_mod


def test_templates_shape_and_range():
    t = data_mod.glyph_templates()
    assert t.shape == (10, 8, 8)
    assert t.min() >= 0 and t.max() <= 16
    # every class template is distinct
    flat = t.reshape(10, -1)
    for i in range(10):
        for j in range(i + 1, 10):
            assert not np.array_equal(flat[i], flat[j]), (i, j)


def test_make_digits_shapes_and_normalization():
    X, y = data_mod.make_digits(n_per_class=20, seed=0)
    assert X.shape == (200, 64)
    assert y.shape == (200,)
    assert X.dtype == np.float32 and y.dtype == np.int32
    assert X.min() >= 0.0 and X.max() <= 1.0
    counts = np.bincount(y, minlength=10)
    assert (counts == 20).all()


def test_make_digits_deterministic():
    X1, y1 = data_mod.make_digits(n_per_class=10, seed=3)
    X2, y2 = data_mod.make_digits(n_per_class=10, seed=3)
    np.testing.assert_array_equal(X1, X2)
    np.testing.assert_array_equal(y1, y2)
    X3, _ = data_mod.make_digits(n_per_class=10, seed=4)
    assert not np.array_equal(X1, X3)


def test_split_stratified_and_disjoint():
    X, y = data_mod.make_digits(n_per_class=50, seed=1)
    xtr, ytr, xte, yte = data_mod.train_test_split(X, y, test_frac=0.2)
    assert xtr.shape[0] == 400 and xte.shape[0] == 100
    assert (np.bincount(yte, minlength=10) == 10).all()
    # disjoint: no test row appears in train
    tr_set = {tuple(r) for r in xtr.round(6)}
    overlap = sum(tuple(r) in tr_set for r in xte.round(6))
    assert overlap == 0


def test_nearest_template_is_informative():
    """Nearest shifted-template classification beats chance by a wide margin —
    the corpus is learnable, as the paper's >90% accuracy curves require.
    (Samples are randomly translated by +/-1 px, so the template bank holds
    all 9 shifts of each glyph.)"""
    X, y = data_mod.make_digits(n_per_class=30, seed=2)
    t = data_mod.glyph_templates()
    bank, labels = [], []
    for c in range(10):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                bank.append(np.roll(np.roll(t[c], dy, axis=0), dx, axis=1).reshape(64) / 16.0)
                labels.append(c)
    bank = np.stack(bank)
    labels = np.array(labels)
    preds = labels[np.argmin(((X[:, None, :] - bank[None]) ** 2).sum(-1), axis=1)]
    acc = (preds == y).mean()
    assert acc > 0.8, acc


def test_dump_csv_roundtrip(tmp_path):
    X, y = data_mod.make_digits(n_per_class=3, seed=5)
    path = os.path.join(tmp_path, "d.csv")
    data_mod.dump_csv(path, X, y)
    rows = open(path).read().strip().split("\n")
    assert len(rows) == 30
    first = rows[0].split(",")
    assert len(first) == 65
    np.testing.assert_allclose(np.array(first[:64], np.float32), X[0], rtol=1e-6)
    assert int(first[64]) == y[0]

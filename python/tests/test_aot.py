"""AOT path: every entry point lowers to parseable HLO text + manifest/CSVs."""

import os

import numpy as np
import pytest

from compile import aot, fed, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_artifacts(out, verbose=False)
    return out


def test_all_entry_points_emitted(built):
    names = set(aot.entry_points().keys())
    assert names == {
        "client_fedscalar_normal",
        "client_fedscalar_rademacher",
        "client_fedscalar_batch_normal",
        "client_fedscalar_batch_rademacher",
        "server_reconstruct_normal",
        "server_reconstruct_rademacher",
        "client_delta",
        "eval",
    }
    for name in names:
        path = os.path.join(built, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text and "ROOT" in text, name
        # must be a tuple-returning module (rust unwraps with to_tuple)
        assert "tuple" in text.lower(), name


def test_manifest_contents(built):
    kv = {}
    for line in open(os.path.join(built, "manifest.txt")):
        k, _, v = line.strip().partition("=")
        kv[k] = v
    assert kv["param_dim"] == str(model.PARAM_DIM)
    assert kv["num_agents"] == "20"
    assert kv["local_steps"] == "5"
    assert kv["batch_size"] == "32"
    assert kv["eval_size"] == "360"
    assert len(kv["entries"].split(",")) == 8


def test_csvs_shapes(built):
    train = open(os.path.join(built, "digits_train.csv")).read().strip().split("\n")
    test = open(os.path.join(built, "digits_test.csv")).read().strip().split("\n")
    assert len(train) == 1440
    assert len(test) == 360
    assert len(train[0].split(",")) == 65


def test_hlo_client_fedscalar_has_rng(built):
    """The client artifact must CONTAIN the threefry RNG (v is regenerated
    from the seed inside the graph — nothing d-dimensional crosses the wire)."""
    text = open(os.path.join(built, "client_fedscalar_normal.hlo.txt")).read()
    # threefry lowers to shifts/xors over u32; look for its signature ops
    assert "xor" in text, "expected threefry xor ops in client HLO"
    srv = open(os.path.join(built, "server_reconstruct_normal.hlo.txt")).read()
    assert "xor" in srv, "expected threefry xor ops in server HLO"


def test_stamp_written(built):
    assert os.path.exists(os.path.join(built, ".stamp"))

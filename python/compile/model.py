"""L2: the Digits MLP forward/backward in JAX, built on the L1 Pallas kernels.

Architecture (paper section III): 64 -> 24 (ReLU) -> 12 (ReLU) -> 10 logits,
softmax cross-entropy loss; d = 1990 trainable parameters ("approximately
2000" in the paper). Parameters live as ONE flat f32[d] vector — that is the
object FedScalar projects, FedAvg ships, and QSGD quantizes, and it keeps the
Rust-side state management to a single Vec<f32>.

Flat layout (row-major): w1[64,24] b1[24] w2[24,12] b2[12] w3[12,10] b3[10].
The Rust nn::mlp module mirrors this layout and math exactly; the integration
suite asserts cross-backend agreement on deltas.

The fused Pallas layers are wrapped in jax.custom_vjp (pallas_call has no
VJP); the backward pass is standard pure-jnp backprop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear

INPUT_DIM = 64
HIDDEN1 = 24
HIDDEN2 = 12
NUM_CLASSES = 10

LAYER_SHAPES = [
    (INPUT_DIM, HIDDEN1),
    (HIDDEN1,),
    (HIDDEN1, HIDDEN2),
    (HIDDEN2,),
    (HIDDEN2, NUM_CLASSES),
    (NUM_CLASSES,),
]

PARAM_DIM = sum(int(jnp.prod(jnp.array(s))) for s in LAYER_SHAPES)  # 1990


def unflatten(params: jnp.ndarray):
    """Split the flat f32[PARAM_DIM] vector into (w1,b1,w2,b2,w3,b3)."""
    out = []
    off = 0
    for shape in LAYER_SHAPES:
        size = 1
        for s in shape:
            size *= s
        out.append(params[off : off + size].reshape(shape))
        off += size
    assert off == PARAM_DIM
    return tuple(out)


def flatten(tensors) -> jnp.ndarray:
    """Inverse of unflatten."""
    return jnp.concatenate([t.reshape(-1) for t in tensors])


# --- fused layers with custom VJP ------------------------------------------


@jax.custom_vjp
def linear(x, w, b):
    return fused_linear(x, w, b, relu=False)


def _linear_fwd(x, w, b):
    return linear(x, w, b), (x, w)


def _linear_bwd(res, g):
    x, w = res
    return g @ w.T, x.T @ g, jnp.sum(g, axis=0)


linear.defvjp(_linear_fwd, _linear_bwd)


@jax.custom_vjp
def linear_relu(x, w, b):
    return fused_linear(x, w, b, relu=True)


def _linear_relu_fwd(x, w, b):
    y = linear_relu(x, w, b)
    return y, (x, w, y)


def _linear_relu_bwd(res, g):
    x, w, y = res
    g = jnp.where(y > 0, g, 0.0)
    return g @ w.T, x.T @ g, jnp.sum(g, axis=0)


linear_relu.defvjp(_linear_relu_fwd, _linear_relu_bwd)


# --- model ------------------------------------------------------------------


def forward(params: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Logits for a batch. params: f32[1990], x: f32[B, 64] -> f32[B, 10]."""
    w1, b1, w2, b2, w3, b3 = unflatten(params)
    h1 = linear_relu(x, w1, b1)
    h2 = linear_relu(h1, w2, b2)
    return linear(h2, w3, b3)


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax-CE. logits: [B, C], labels: int [B]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def loss_fn(params: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return softmax_cross_entropy(forward(params, x), y)


grad_fn = jax.grad(loss_fn)
value_and_grad_fn = jax.value_and_grad(loss_fn)


def local_sgd(params: jnp.ndarray, xb: jnp.ndarray, yb: jnp.ndarray, alpha) -> tuple:
    """S plain SGD steps (Algorithm 1, ClientStage lines 18-21).

    xb: f32[S, B, 64], yb: int32[S, B]. Returns (delta f32[1990], mean_loss).
    delta = psi_S - psi_0 — the quantity FedScalar projects.
    """

    def step(p, batch):
        bx, by = batch
        loss, g = value_and_grad_fn(p, bx, by)
        return p - alpha * g, loss

    final, losses = jax.lax.scan(step, params, (xb, yb))
    return final - params, jnp.mean(losses)


def accuracy(params: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    preds = jnp.argmax(forward(params, x), axis=-1)
    return jnp.mean((preds == y).astype(jnp.float32))


def evaluate(params: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """(loss, accuracy) on a fixed evaluation set."""
    logits = forward(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss = jnp.mean(logz - picked)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def init_params(seed: int = 0) -> jnp.ndarray:
    """Glorot-uniform weights, zero biases, as one flat vector.

    Mirrored bit-for-bit *in spirit* by rust nn::init (both use the same
    limit sqrt(6/(fan_in+fan_out))); exact RNG streams differ, which is fine
    because params are always passed across the boundary explicitly.
    """
    key = jax.random.PRNGKey(seed)
    tensors = []
    for shape in LAYER_SHAPES:
        if len(shape) == 2:
            key, sub = jax.random.split(key)
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            tensors.append(jax.random.uniform(sub, shape, jnp.float32, -limit, limit))
        else:
            tensors.append(jnp.zeros(shape, jnp.float32))
    return flatten(tensors)

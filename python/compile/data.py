"""Synthetic Digits-like dataset (substitute for sklearn.datasets.load_digits).

The paper evaluates on sklearn's Digits: 8x8 grayscale images (64 features,
pixel values 0..16), 10 classes, ~1800 samples. sklearn is not available in
this environment, so we procedurally generate an equivalent corpus from ten
hand-authored 8x8 glyph templates with per-sample intensity jitter, additive
pixel noise, and +/-1 pixel translations. The generator is deterministic
(numpy Generator with a fixed seed) and is dumped to CSV at artifact-build
time so the Rust coordinator and the JAX test-suite consume byte-identical
data. See DESIGN.md section 5 (Substitutions).
"""

from __future__ import annotations

import numpy as np

# 8x8 glyph templates, '#' = full intensity (16), '+' = half (8), '.' = off.
# Drawn to mimic the low-res anti-aliased look of the original Digits scans.
_GLYPHS = [
    # 0
    [".+###+..",
     "+#...#+.",
     "#+...+#.",
     "#.....#.",
     "#.....#.",
     "#+...+#.",
     "+#...#+.",
     ".+###+.."],
    # 1
    ["...##...",
     "..+##...",
     ".+.##...",
     "...##...",
     "...##...",
     "...##...",
     "...##...",
     ".+####+."],
    # 2
    [".+###+..",
     "#+...#+.",
     ".....##.",
     "....+#..",
     "...+#+..",
     "..+#+...",
     ".+#+....",
     "+######."],
    # 3
    [".####+..",
     "....+#+.",
     ".....#+.",
     "..+##+..",
     ".....#+.",
     ".....+#.",
     "#+...+#.",
     ".+###+.."],
    # 4
    ["....+#..",
     "...+##..",
     "..+#+#..",
     ".+#.+#..",
     "+#..+#..",
     "########",
     "....+#..",
     "....+#.."],
    # 5
    ["+#####..",
     "+#......",
     "+#......",
     "+####+..",
     ".....#+.",
     "......#.",
     "+#...+#.",
     ".+###+.."],
    # 6
    ["..+###..",
     ".+#+....",
     "+#......",
     "+####+..",
     "+#...#+.",
     "#.....#.",
     "+#...#+.",
     ".+###+.."],
    # 7
    ["#######.",
     ".....+#.",
     "....+#..",
     "....#+..",
     "...+#...",
     "...#+...",
     "..+#....",
     "..##...."],
    # 8
    [".+###+..",
     "+#...#+.",
     "+#...#+.",
     ".+###+..",
     "+#...#+.",
     "#.....#.",
     "+#...#+.",
     ".+###+.."],
    # 9
    [".+###+..",
     "+#...#+.",
     "#.....#.",
     "+#...##.",
     ".+###+#.",
     "......#.",
     "....+#+.",
     "..###+.."],
]

_CHAR_VAL = {".": 0.0, "+": 8.0, "#": 16.0}

NUM_CLASSES = 10
IMG_SIDE = 8
NUM_FEATURES = IMG_SIDE * IMG_SIDE  # 64


def glyph_templates() -> np.ndarray:
    """Return the ten class templates as a float32 array [10, 8, 8] in 0..16."""
    t = np.zeros((NUM_CLASSES, IMG_SIDE, IMG_SIDE), dtype=np.float32)
    for c, rows in enumerate(_GLYPHS):
        assert len(rows) == IMG_SIDE
        for i, row in enumerate(rows):
            assert len(row) == IMG_SIDE
            for j, ch in enumerate(row):
                t[c, i, j] = _CHAR_VAL[ch]
    return t


def make_digits(
    n_per_class: int = 180,
    seed: int = 0,
    noise_std: float = 1.5,
    intensity_jitter: float = 0.3,
    max_shift: int = 1,
):
    """Generate the synthetic Digits corpus.

    Returns (X, y): X float32 [n_per_class*10, 64] normalized to [0, 1]
    (raw pixel range 0..16 divided by 16, like common Digits preprocessing),
    y int32 [n]. Samples are interleaved by class then shuffled.
    """
    rng = np.random.default_rng(seed)
    templates = glyph_templates()
    n = n_per_class * NUM_CLASSES
    X = np.zeros((n, IMG_SIDE, IMG_SIDE), dtype=np.float32)
    y = np.zeros((n,), dtype=np.int32)
    idx = 0
    for c in range(NUM_CLASSES):
        for _ in range(n_per_class):
            img = templates[c].copy()
            # per-sample global intensity jitter
            img *= 1.0 + rng.uniform(-intensity_jitter, intensity_jitter)
            # small translation
            if max_shift > 0:
                dx = rng.integers(-max_shift, max_shift + 1)
                dy = rng.integers(-max_shift, max_shift + 1)
                img = np.roll(np.roll(img, dy, axis=0), dx, axis=1)
            # additive pixel noise
            img += rng.normal(0.0, noise_std, size=img.shape)
            img = np.clip(img, 0.0, 16.0)
            X[idx] = img
            y[idx] = c
            idx += 1
    perm = rng.permutation(n)
    X = X[perm].reshape(n, NUM_FEATURES) / 16.0
    y = y[perm]
    return X.astype(np.float32), y.astype(np.int32)


def train_test_split(X: np.ndarray, y: np.ndarray, test_frac: float = 0.2, seed: int = 1):
    """Deterministic stratified split. Returns (Xtr, ytr, Xte, yte)."""
    rng = np.random.default_rng(seed)
    train_idx, test_idx = [], []
    for c in range(NUM_CLASSES):
        cls = np.where(y == c)[0]
        cls = cls[rng.permutation(len(cls))]
        n_test = int(round(len(cls) * test_frac))
        test_idx.extend(cls[:n_test].tolist())
        train_idx.extend(cls[n_test:].tolist())
    train_idx = np.array(sorted(train_idx))
    test_idx = np.array(sorted(test_idx))
    return X[train_idx], y[train_idx], X[test_idx], y[test_idx]


def dump_csv(path: str, X: np.ndarray, y: np.ndarray) -> None:
    """Write rows of `f0,...,f63,label` with full float precision."""
    with open(path, "w") as f:
        for row, label in zip(X, y):
            f.write(",".join(repr(float(v)) for v in row))
            f.write(f",{int(label)}\n")

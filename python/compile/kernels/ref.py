"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations the pytest/hypothesis suite checks
every kernel against (assert_allclose). They are also what the Rust
PureRustBackend mirrors, so any disagreement between layers is caught here.
"""

import jax.numpy as jnp


def projection_ref(delta: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Scalar projection r = <delta, v> (paper eq. (3))."""
    return jnp.vdot(delta, v)


def reconstruct_ref(r: jnp.ndarray, vs: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized reconstruction sum_n r_n v_n (paper eq. (4) before 1/N).

    r: [N], vs: [N, D] -> [D]
    """
    return r @ vs


def linear_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Affine layer x @ w + b."""
    return x @ w + b


def linear_relu_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fused affine + ReLU."""
    return jnp.maximum(x @ w + b, 0.0)

"""L1 Pallas kernel: fused affine (+ReLU) layer for the Digits MLP.

The local-SGD client stage runs S forward/backward passes per round; the
dense work is three small matmuls per pass. Each layer is fused into a single
VMEM-resident kernel (x @ w + b, optionally ReLU) — all three layers of the
64->24->12->10 model fit comfortably in one block, so no grid is needed.

Autodiff: pallas_call has no registered VJP, so model.py wraps these in
jax.custom_vjp with a pure-jnp backward pass (the standard pattern).

interpret=True is mandatory for CPU PJRT execution (see projection.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...] + b_ref[...]


def _linear_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] @ w_ref[...] + b_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("relu",))
def fused_linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, relu: bool = False) -> jnp.ndarray:
    """Fused x @ w + b (+ ReLU). x: [B, IN], w: [IN, OUT], b: [OUT] -> [B, OUT]."""
    batch, d_in = x.shape
    d_in2, d_out = w.shape
    assert d_in == d_in2, f"inner-dim mismatch {d_in} vs {d_in2}"
    assert b.shape == (d_out,)
    kernel = _linear_relu_kernel if relu else _linear_kernel
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((batch, d_out), x.dtype),
        interpret=True,
    )(x, w, b)

"""L1 Pallas kernel: blocked reconstruction sum_n r_n * v_n.

Server-side decoding hot-spot of FedScalar (Algorithm 1, lines 9-12): the
received scalars r[N] are projected back onto the regenerated random vectors
V[N, d] and summed. Expressed as the mat-vec r^T @ V, tiled along d.

TPU mapping (DESIGN.md section 6): each grid step holds the full r vector
resident in VMEM (N=20 is tiny) and streams one [N, block] tile of V,
producing one [block] output tile — a [1,N]x[N,block] MXU matmul per step.
On real hardware the V tile is regenerated in VMEM from the seeds.

interpret=True is mandatory for CPU PJRT execution (see projection.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _reconstruct_kernel(r_ref, v_ref, o_ref):
    """Grid step j: o_block = r @ V[:, block_j]."""
    o_ref[...] = r_ref[...] @ v_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def reconstruct(r: jnp.ndarray, vs: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Blocked r^T @ V for r: [N], V: [N, D] (D block-divisible) -> [D]."""
    (n,) = r.shape
    n2, d = vs.shape
    assert n == n2, f"N mismatch {n} vs {n2}"
    assert d % block == 0, f"d={d} not a multiple of block={block}; pad first"
    grid = d // block
    return pl.pallas_call(
        _reconstruct_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((n, block), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(r, vs)

"""L1 Pallas kernel: blocked scalar projection r = <delta, v>.

This is the client-side encoding hot-spot of FedScalar (Algorithm 1, line 22):
the d-dimensional local update difference is collapsed to ONE scalar by an
inner product with the seeded random vector v.

TPU mapping (DESIGN.md section 6): delta and v are streamed through VMEM in
lane-aligned blocks; a scalar accumulator lives across the 1-D grid. On real
TPU hardware the v block would be generated in-register from the seed via
pltpu.prng_random_bits so v never touches HBM — mirroring the paper's point
that v is never transmitted. Under interpret=True (CPU PJRT) we pass v in;
the block schedule is identical.

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128-lane alignment; 2048 = 16 blocks for the padded d=1990 model.
DEFAULT_BLOCK = 128


def _projection_kernel(d_ref, v_ref, o_ref):
    """Grid step i: o += sum(delta_block * v_block)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.sum(d_ref[...] * v_ref[...])
    o_ref[...] += part.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("block",))
def projection(delta: jnp.ndarray, v: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Blocked inner product of two 1-D vectors of equal, block-divisible size.

    Returns a scalar f32. Callers zero-pad to a multiple of `block`
    (padding contributes nothing to the dot product).
    """
    (d,) = delta.shape
    assert v.shape == (d,), f"shape mismatch {delta.shape} vs {v.shape}"
    assert d % block == 0, f"d={d} not a multiple of block={block}; pad first"
    grid = d // block
    out = pl.pallas_call(
        _projection_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(delta, v)
    return out[0]


def pad_to_block(x: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Zero-pad the trailing axis of a 1-D or 2-D array to a block multiple."""
    d = x.shape[-1]
    rem = (-d) % block
    if rem == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad)

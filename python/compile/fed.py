"""L2: FedScalar client/server stages and baseline entry points (Algorithm 1).

Every function here is an AOT entry point lowered to HLO text by aot.py and
executed from the Rust coordinator. The seed round-trip property — the client
artifact and the server artifact regenerate the *bit-identical* random vector
v from the same 32-bit seed — holds because both lower the same
jax.random.{normal,rademacher}(PRNGKey(seed), (d,)) threefry computation.

Distributions (paper section II-A): 'normal' is the baseline analysis case;
'rademacher' reduces the aggregation variance by (2/N^2) sum_n ||delta_n||^2
(Proposition 2.1) and is the recommended default.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model
from .kernels.projection import projection, pad_to_block
from .kernels.reconstruct import reconstruct

DISTRIBUTIONS = ("normal", "rademacher")


def sample_v(seed, dist: str, dim: int = model.PARAM_DIM) -> jnp.ndarray:
    """The shared random vector v_{k,n} ~ N(0, I) or Rademacher^d.

    `seed` may be a traced uint32 scalar — it is an HLO input, which is what
    lets the server regenerate v from the client's uploaded seed alone.
    """
    key = jax.random.PRNGKey(seed)
    if dist == "normal":
        return jax.random.normal(key, (dim,), jnp.float32)
    if dist == "rademacher":
        return jax.random.rademacher(key, (dim,), jnp.float32)
    raise ValueError(f"unknown distribution {dist!r}")


def client_fedscalar(params, xb, yb, seed, alpha, *, dist: str):
    """ClientStage (Algorithm 1 lines 15-24): S local SGD steps, then encode.

    Inputs: params f32[d], xb f32[S,B,64], yb int32[S,B], seed uint32[],
    alpha f32[]. Returns (r f32[], mean_loss f32[], delta_sq_norm f32[]).

    The third output is ||delta||^2 — it costs nothing extra, never leaves
    the simulation boundary (it is NOT part of the 2-scalar wire payload),
    and lets the harness report Prop 2.1's variance-gap term exactly.
    """
    delta, loss = model.local_sgd(params, xb, yb, alpha)
    v = sample_v(seed, dist)
    r = projection(pad_to_block(delta), pad_to_block(v))
    return r, loss, jnp.sum(delta * delta)


def server_reconstruct(rs, seeds, *, dist: str):
    """Server aggregation (Algorithm 1 lines 7-12).

    rs: f32[N], seeds: uint32[N] -> ghat f32[d] = (1/N) sum_n r_n v(seed_n).
    """
    vs = jax.vmap(lambda s: sample_v(s, dist))(seeds)
    n = rs.shape[0]
    ghat_pad = reconstruct(rs, pad_to_block(vs))
    return ghat_pad[: model.PARAM_DIM] / n


def client_fedscalar_batch(params, xbs, ybs, seeds, alpha, *, dist: str):
    """All N client stages in ONE lowered computation (vmap over agents).

    §Perf L2/L3 optimization: collapses the coordinator's N per-round PJRT
    dispatches into one. xbs: f32[N,S,B,64], ybs: int32[N,S,B],
    seeds: uint32[N]. Returns (rs f32[N], losses f32[N], dsqs f32[N]).
    The math is per-agent identical to `client_fedscalar`.
    """
    fn = lambda xb, yb, seed: client_fedscalar(params, xb, yb, seed, alpha, dist=dist)
    return jax.vmap(fn)(xbs, ybs, seeds)


def client_delta(params, xb, yb, alpha):
    """Baseline client stage: same local SGD, but the full d-vector leaves.

    Used by FedAvg (ships delta verbatim) and QSGD (quantizes delta in the
    Rust coordinator, which owns the wire-format accounting).
    Returns (delta f32[d], mean_loss f32[]).
    """
    return model.local_sgd(params, xb, yb, alpha)


def evaluate(params, x, y):
    """(loss, accuracy) on a fixed evaluation split."""
    return model.evaluate(params, x, y)

"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once by `make artifacts`; Python never runs on the round path. The Rust
runtime (rust/src/runtime/) loads these with HloModuleProto::from_text_file,
compiles them on the PJRT CPU client, and executes them for every federated
round.

HLO TEXT is the interchange format, NOT lowered.compile()/.serialize():
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also dumps the synthetic Digits CSVs (shared bytes between Rust and the
pytest suite) and a key=value manifest the Rust side validates against.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import fed, model

# Shapes baked into the artifacts — the experiment configuration of the
# paper's section III. The manifest records them; Rust refuses to run a
# config that disagrees with the artifacts it loaded.
NUM_AGENTS = 20       # N
LOCAL_STEPS = 5       # S
BATCH_SIZE = 32       # B
EVAL_SIZE = 360       # 20% of 1800 synthetic Digits samples
PARAM_DIM = model.PARAM_DIM  # d = 1990

F32 = jnp.float32
I32 = jnp.int32
U32 = jnp.uint32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """name -> (fn, arg_specs). Argument ORDER is the Rust-side ABI."""
    params = spec((PARAM_DIM,), F32)
    xb = spec((LOCAL_STEPS, BATCH_SIZE, model.INPUT_DIM), F32)
    yb = spec((LOCAL_STEPS, BATCH_SIZE), I32)
    seed = spec((), U32)
    alpha = spec((), F32)
    rs = spec((NUM_AGENTS,), F32)
    seeds = spec((NUM_AGENTS,), U32)
    ex = spec((EVAL_SIZE, model.INPUT_DIM), F32)
    ey = spec((EVAL_SIZE,), I32)

    xbs = spec((NUM_AGENTS, LOCAL_STEPS, BATCH_SIZE, model.INPUT_DIM), F32)
    ybs = spec((NUM_AGENTS, LOCAL_STEPS, BATCH_SIZE), I32)

    eps = {}
    for dist in fed.DISTRIBUTIONS:
        eps[f"client_fedscalar_{dist}"] = (
            functools.partial(fed.client_fedscalar, dist=dist),
            (params, xb, yb, seed, alpha),
        )
        eps[f"client_fedscalar_batch_{dist}"] = (
            functools.partial(fed.client_fedscalar_batch, dist=dist),
            (params, xbs, ybs, seeds, alpha),
        )
        eps[f"server_reconstruct_{dist}"] = (
            functools.partial(fed.server_reconstruct, dist=dist),
            (rs, seeds),
        )
    eps["client_delta"] = (fed.client_delta, (params, xb, yb, alpha))
    eps["eval"] = (fed.evaluate, (params, ex, ey))
    return eps


def build_artifacts(out_dir: str, verbose: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)

    # --- HLO artifacts ------------------------------------------------------
    names = []
    for name, (fn, specs) in entry_points().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        names.append(name)
        if verbose:
            print(f"  wrote {path} ({len(text)} chars)")

    # --- dataset ------------------------------------------------------------
    X, y = data_mod.make_digits()
    xtr, ytr, xte, yte = data_mod.train_test_split(X, y)
    assert xte.shape[0] == EVAL_SIZE, (xte.shape, EVAL_SIZE)
    data_mod.dump_csv(os.path.join(out_dir, "digits_train.csv"), xtr, ytr)
    data_mod.dump_csv(os.path.join(out_dir, "digits_test.csv"), xte, yte)
    if verbose:
        print(f"  wrote digits_train.csv ({xtr.shape[0]} rows), digits_test.csv ({xte.shape[0]} rows)")

    # --- manifest (validated by rust runtime::artifacts) ---------------------
    eval_note = "client_fedscalar_batch_* are optional fast-path entries (vmapped over N agents)"
    manifest = [
        f"param_dim={PARAM_DIM}",
        f"num_agents={NUM_AGENTS}",
        f"local_steps={LOCAL_STEPS}",
        f"batch_size={BATCH_SIZE}",
        f"eval_size={EVAL_SIZE}",
        f"input_dim={model.INPUT_DIM}",
        f"num_classes={model.NUM_CLASSES}",
        f"entries={','.join(names)}",
        f"note={eval_note}",
    ]
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")

    # stamp for Makefile freshness tracking
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    if verbose:
        print(f"  wrote manifest.txt + .stamp — {len(names)} HLO entry points")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    build_artifacts(args.out_dir, verbose=not args.quiet)


if __name__ == "__main__":
    main()

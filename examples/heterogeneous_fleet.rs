//! Heterogeneous-fleet scenario sweep: sampling policy × availability
//! trace, on a fleet with a 4x compute-speed spread and a straggler
//! deadline — the regime the paper's all-clients-every-round §III setup
//! cannot express, and where FedScalar's dimension-free uplink matters
//! most (a dropped 64-bit upload wastes 1.28 mJ; a dropped FedAvg upload
//! wastes a thousand times that).
//!
//! Runs a seeded sweep over {full, uniform-k, deadline-aware} client
//! sampling × {always-on, duty-cycle, churn} availability and writes a
//! per-scenario summary CSV (wall-clock, energy, accuracy, bits).
//!
//!     cargo run --release --example heterogeneous_fleet
//!     cargo run --release --example heterogeneous_fleet -- --rounds 300 --out results/fleet.csv

use fedscalar::algo::Method;
use fedscalar::config::{DataSource, ExperimentConfig};
use fedscalar::coordinator::engine::run_pure_rust;
use fedscalar::coordinator::Engine;
use fedscalar::error::Result;
use fedscalar::metrics::RunHistory;
use fedscalar::rng::VDistribution;
use fedscalar::runtime::PureRustBackend;
use fedscalar::simnet::{Availability, SamplerPolicy};
use fedscalar::util::cli::Args;
use fedscalar::util::csv::CsvWriter;

/// Run one scenario and also report how many devices drained their
/// battery (the engine owns the SimNet, so `run_pure_rust` can't see it).
fn run_with_battery_report(cfg: &ExperimentConfig, seed: u64) -> Result<(RunHistory, usize)> {
    let mut be = PureRustBackend::new(&cfg.model);
    be.set_shape(cfg.fed.local_steps, cfg.fed.batch_size);
    let mut engine = Engine::from_config(cfg, Box::new(be), seed)?;
    let h = engine.run()?;
    Ok((h, engine.exhausted_clients()))
}

fn main() -> Result<()> {
    fedscalar::util::logger::init_from_env();
    let a = Args::new(
        "heterogeneous_fleet",
        "sampling-policy x availability sweep on a heterogeneous fleet",
    )
    .opt("rounds", "150", "rounds per scenario run")
    .opt("agents", "12", "fleet size")
    .opt("alpha", "0.01", "local stepsize")
    .opt("run-seed", "0", "run seed")
    .opt("out", "results/heterogeneous_fleet.csv", "summary CSV path")
    .parse(std::env::args().skip(1))?;

    let samplers = [
        SamplerPolicy::Full,
        SamplerPolicy::UniformK(6),
        SamplerPolicy::DeadlineAware { target: 6, over: 2 },
    ];
    let traces = [
        Availability::AlwaysOn,
        Availability::DutyCycle { period: 3, on: 2 },
        Availability::Churn { p_off: 0.2 },
    ];

    let mut base = ExperimentConfig::smoke();
    base.data = DataSource::Synthetic;
    base.fed.method = Method::fedscalar(VDistribution::Rademacher, 1);
    base.fed.num_agents = a.get_usize("agents")?;
    base.fed.rounds = a.get_usize("rounds")?;
    base.fed.eval_every = (base.fed.rounds / 10).max(1);
    base.fed.alpha = a.get_f64("alpha")? as f32;
    base.scenario.fleet.compute_spread = 3.0; // multipliers in [1/4, 4]
    let run_seed = a.get_u64("run-seed")?;

    // calibrate the deadline from the always-on full-participation pace:
    // tight enough that the slowest quartile misses it — and a per-client
    // energy budget that roughly half the sweep's rounds can drain, so
    // battery exhaustion is visible in the grid
    let probe = run_pure_rust(&base, run_seed)?;
    let last_probe = probe.records.last().unwrap();
    let mean_round = last_probe.cum_sim_seconds / base.fed.rounds as f64;
    let deadline = 0.75 * mean_round;
    let per_client_round_j =
        last_probe.cum_energy_joules / (base.fed.rounds * base.fed.num_agents) as f64;
    let budget = 0.5 * per_client_round_j * base.fed.rounds as f64;
    base.scenario.fleet.energy_budget_j = budget;
    println!(
        "fleet: N={} compute spread 4x, deadline {:.3} s (75% of mean round {:.3} s),\n\
         battery {:.4} J/client (~half the sweep's upload energy)\n",
        base.fed.num_agents, deadline, mean_round, budget
    );

    let out_path = a.get("out");
    let mut csv = CsvWriter::create(
        &out_path,
        &[
            "sampler",
            "availability",
            "final_acc",
            "sim_seconds",
            "energy_joules",
            "uplink_bits",
            "downlink_bits",
            "exhausted",
        ],
    )?;
    println!(
        "{:<14} {:<10} {:>9} {:>12} {:>11} {:>12} {:>14} {:>10}",
        "sampler", "avail", "acc", "sim_s", "joules", "up_bits", "down_bits", "exhausted"
    );
    for sampler in samplers {
        for trace in traces {
            let mut cfg = base.clone();
            cfg.scenario.sampler = sampler;
            cfg.scenario.availability = trace;
            cfg.scenario.deadline_s = Some(deadline);
            let (h, exhausted) = run_with_battery_report(&cfg, run_seed)?;
            let last = h.records.last().unwrap();
            println!(
                "{:<14} {:<10} {:>8.1}% {:>12.2} {:>11.4} {:>12} {:>14} {:>7}/{}",
                sampler.name(),
                trace.name(),
                100.0 * last.test_acc,
                last.cum_sim_seconds,
                last.cum_energy_joules,
                last.cum_bits,
                last.cum_downlink_bits,
                exhausted,
                cfg.fed.num_agents,
            );
            csv.row_str(&[
                sampler.name(),
                trace.name(),
                format!("{:.4}", last.test_acc),
                format!("{:.6}", last.cum_sim_seconds),
                format!("{:.6}", last.cum_energy_joules),
                format!("{}", last.cum_bits),
                format!("{}", last.cum_downlink_bits),
                format!("{exhausted}"),
            ])?;
        }
    }
    csv.flush()?;
    println!(
        "\nsummary written to {out_path}\n\
         deadline-aware over-selection keeps the round tight without starving\n\
         aggregation; sub-sampling policies also spread the battery load, so\n\
         fewer devices exhaust their budget than under full participation —\n\
         and FedScalar's 64-bit uplink makes every dropped straggler nearly\n\
         free in energy. Rerun with --rounds for tighter accuracy."
    );
    Ok(())
}

//! Proposition 2.1 ablation: measure the aggregation-variance gap between
//! Gaussian and Rademacher projection vectors and compare it to the
//! paper's closed form
//!
//!     Var_N(0,I)[d_x] - Var_Rademacher[d_x] = (2/N^2) sum_n ||delta_n||^2 I_d
//!
//! then show the end-to-end consequence: the Rademacher variant's accuracy
//! curve dominates the Gaussian one (paper Figs 2-3).
//!
//!     cargo run --release --example rademacher_ablation

use fedscalar::algo::projection::Projector;
use fedscalar::algo::Method;
use fedscalar::config::{DataSource, ExperimentConfig};
use fedscalar::coordinator::engine::run_pure_rust;
use fedscalar::error::Result;
use fedscalar::rng::{VDistribution, Xoshiro256};
use fedscalar::tensor;

fn main() -> Result<()> {
    fedscalar::util::logger::init_from_env();

    // --- Part 1: Monte-Carlo check of the closed form -----------------------
    // (d=64, N=4: the gap is a 2/(d+2) fraction of the total second moment,
    // so it is only Monte-Carlo-resolvable at moderate d — the full-d
    // control-variate check lives in `cargo bench --bench variance_ablation`)
    let d = 64;
    let n_agents = 4;
    let trials = 30_000;
    let mut rng = Xoshiro256::seed_from(0);
    // fixed per-agent deltas (as after one ClientStage)
    let deltas: Vec<Vec<f32>> = (0..n_agents)
        .map(|_| (0..d).map(|_| rng.uniform_in(-0.5, 0.5)).collect())
        .collect();
    let sum_dsq: f64 = deltas.iter().map(|dl| tensor::norm_sq(dl) as f64).sum();
    let predicted_gap_trace = 2.0 / (n_agents as f64).powi(2) * sum_dsq; // per-coordinate mean x d

    let mean_e2 = |dist: VDistribution, base: u32| -> f64 {
        let mut proj = Projector::new(d, dist);
        let mut acc = 0.0f64;
        for t in 0..trials {
            let mut dx = vec![0.0f32; d];
            for (a, delta) in deltas.iter().enumerate() {
                let seed = base + (t * n_agents + a) as u32;
                let r = proj.encode(delta, seed);
                proj.decode_into(&mut dx, seed, &[r], 1.0 / n_agents as f32);
            }
            acc += tensor::norm_sq(&dx) as f64; // E||dx||^2 (trace of 2nd moment)
        }
        acc / trials as f64
    };
    let e2_gauss = mean_e2(VDistribution::Normal, 1);
    let e2_rad = mean_e2(VDistribution::Rademacher, 1_000_000_000);
    let measured_gap = e2_gauss - e2_rad; // mean-square terms cancel in expectation
    println!("=== Proposition 2.1: aggregation variance gap (trace form) ===");
    println!("d={d}, N={n_agents}, {trials} Monte-Carlo rounds");
    println!("E||d_x||^2 Gaussian    : {e2_gauss:.3}");
    println!("E||d_x||^2 Rademacher  : {e2_rad:.3}");
    println!("measured gap           : {measured_gap:.3}");
    println!("paper closed form      : {predicted_gap_trace:.3}   (2/N^2 * sum ||delta||^2 * tr I / d... trace)");
    let rel = (measured_gap - predicted_gap_trace).abs() / predicted_gap_trace;
    println!("relative error         : {:.1}%", rel * 100.0);
    assert!(rel < 0.5, "Monte-Carlo gap should match Prop 2.1");

    // --- Part 2: end-to-end accuracy consequence ----------------------------
    println!("\n=== End-to-end: Gaussian vs Rademacher FedScalar ===");
    let mut cfg = ExperimentConfig::paper_section_iii();
    cfg.data = DataSource::Synthetic;
    cfg.fed.rounds = 600;
    cfg.fed.eval_every = 100;
    cfg.fed.alpha = 0.01;
    let mut acc_of = |dist: VDistribution| -> Result<Vec<f64>> {
        cfg.fed.method = Method::fedscalar(dist, 1);
        let runs: Vec<Vec<f64>> = (0..5)
            .map(|s| Ok(run_pure_rust(&cfg, s)?.series(|r| r.test_acc)))
            .collect::<Result<_>>()?;
        Ok(fedscalar::util::stats::mean_series(&runs))
    };
    let acc_g = acc_of(VDistribution::Normal)?;
    let acc_r = acc_of(VDistribution::Rademacher)?;
    println!("round   gaussian   rademacher");
    let rounds = [0usize, 100, 200, 300, 400, 500, 599];
    for (i, r) in rounds.iter().enumerate() {
        if i < acc_g.len() {
            println!(
                "{:>5}   {:>7.2}%   {:>9.2}%",
                r,
                acc_g[i] * 100.0,
                acc_r[i] * 100.0
            );
        }
    }
    let (fg, fr) = (*acc_g.last().unwrap(), *acc_r.last().unwrap());
    println!(
        "\nfinal: rademacher {:.2}% vs gaussian {:.2}% — {}",
        fr * 100.0,
        fg * 100.0,
        if fr >= fg {
            "variance reduction visible end-to-end (paper Figs 2-3)"
        } else {
            "NOTE: ordering not reproduced at this seed count"
        }
    );
    Ok(())
}

//! Bandwidth sweep: time-to-accuracy across uplink rates.
//!
//! Sweeps the nominal uplink bandwidth over the LPWAN-to-LTE range of
//! Table I and reports, for FedScalar vs FedAvg vs QSGD, the simulated
//! wall-clock time (eq. 12) needed to reach a target test accuracy — the
//! "wall-clock time-to-accuracy" gold-standard metric the paper's
//! introduction argues for.
//!
//!     cargo run --release --example bandwidth_sweep
//!     cargo run --release --example bandwidth_sweep -- --target 0.8 --rounds 800

use fedscalar::algo::Method;
use fedscalar::config::{DataSource, ExperimentConfig};
use fedscalar::coordinator::engine::run_pure_rust;
use fedscalar::error::Result;
use fedscalar::rng::VDistribution;
use fedscalar::util::cli::Args;
use fedscalar::util::stats;

fn main() -> Result<()> {
    fedscalar::util::logger::init_from_env();
    let a = Args::new("bandwidth_sweep", "time-to-accuracy across uplink rates")
        .opt("target", "0.85", "target test accuracy")
        .opt("rounds", "1000", "max rounds per run")
        .opt("alpha", "0.01", "local stepsize")
        .parse(std::env::args().skip(1))?;
    let target = a.get_f64("target")?;

    let bandwidths_kbps = [1.0, 10.0, 50.0, 100.0, 1000.0];
    let methods = [
        Method::fedscalar(VDistribution::Rademacher, 1),
        Method::qsgd(8),
        Method::fedavg(),
    ];

    println!(
        "time to {:.0}% accuracy (simulated seconds, eq. 12; TDMA, N=20, lognormal fading)\n",
        target * 100.0
    );
    print!("{:<14}", "bandwidth");
    for m in &methods {
        print!("{:>22}", m.name());
    }
    println!();

    for &kbps in &bandwidths_kbps {
        print!("{:<14}", format!("{kbps} kbps"));
        for method in &methods {
            let mut cfg = ExperimentConfig::paper_section_iii();
            cfg.data = DataSource::Synthetic; // artifact-free example
            cfg.fed.rounds = a.get_usize("rounds")?;
            cfg.fed.eval_every = 10;
            cfg.fed.alpha = a.get_f64("alpha")? as f32;
            cfg.fed.method = method.clone();
            cfg.network.channel.nominal_bps = kbps * 1000.0;
            let h = run_pure_rust(&cfg, 0)?;
            let t = stats::first_crossing(
                &h.series(|r| r.cum_sim_seconds),
                &h.series(|r| r.test_acc),
                target,
            );
            match t {
                Some(secs) => print!("{:>20.1} s", secs),
                None => print!(
                    "{:>22}",
                    format!("never ({:.0}%)", h.final_accuracy() * 100.0)
                ),
            }
        }
        println!();
    }
    println!(
        "\nFedScalar's 64-bit upload makes time-to-accuracy nearly bandwidth-\n\
         independent; FedAvg and QSGD degrade with the uplink rate (Table I dynamics)."
    );
    Ok(())
}

//! END-TO-END DRIVER: the full three-layer stack on the paper's §III
//! workload.
//!
//! L3 (this Rust coordinator) drives L2 (the JAX model AOT-lowered to HLO,
//! executed via PJRT) which embeds L1 (the Pallas projection/reconstruction
//! and fused-linear kernels). Python is not running anywhere in this
//! process — only `artifacts/*.hlo.txt` is consumed.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!     cargo run --release --example e2e_train -- --rounds 1500   # full paper run
//!
//! Logs the loss curve and the headline communication metrics; the run is
//! recorded in EXPERIMENTS.md.

use fedscalar::algo::Method;
use fedscalar::config::ExperimentConfig;
use fedscalar::coordinator::Engine;
use fedscalar::error::Result;
use fedscalar::rng::VDistribution;
use fedscalar::runtime::{Backend, XlaBackend};
use fedscalar::util::cli::Args;

fn main() -> Result<()> {
    fedscalar::util::logger::init_from_env();
    let a = Args::new("e2e_train", "end-to-end three-layer training driver")
        .opt("rounds", "300", "communication rounds (paper: 1500)")
        .opt("eval-every", "25", "evaluation cadence")
        .opt("method", "fedscalar-rademacher", "strategy")
        .opt("alpha", "0.003", "local stepsize (paper: 0.003)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("out", "results/e2e_train.csv", "history CSV")
        .parse(std::env::args().skip(1))?;

    let mut cfg = ExperimentConfig::paper_section_iii();
    cfg.fed.rounds = a.get_usize("rounds")?;
    cfg.fed.eval_every = a.get_usize("eval-every")?;
    cfg.fed.alpha = a.get_f64("alpha")? as f32;
    cfg.fed.method = Method::parse(&a.get("method"))
        .unwrap_or_else(|| Method::fedscalar(VDistribution::Rademacher, 1));
    cfg.artifacts_dir = a.get("artifacts").into();

    let backend = XlaBackend::load(&cfg.artifacts_dir)?;
    println!(
        "loaded {} HLO entry points on PJRT platform {:?} (d = {})",
        backend.manifest().entries.len(),
        backend.platform(),
        backend.param_dim()
    );
    backend.manifest().check_compatible(
        cfg.model.param_dim(),
        cfg.fed.num_agents,
        cfg.fed.local_steps,
        cfg.fed.batch_size,
    )?;

    let t0 = std::time::Instant::now();
    let mut engine = Engine::from_config(&cfg, Box::new(backend), 0)?;
    let history = engine.run()?;
    let host_s = t0.elapsed().as_secs_f64();

    println!("\nround  train_loss  test_loss  test_acc   sim_time_s");
    for r in &history.records {
        println!(
            "{:>5}  {:>10.4}  {:>9.4}  {:>7.2}%  {:>10.2}",
            r.round,
            r.train_loss,
            r.test_loss,
            r.test_acc * 100.0,
            r.cum_sim_seconds
        );
    }
    let last = history.records.last().expect("history non-empty");
    println!(
        "\n=== e2e summary ===\n\
         method            : {}\n\
         backend           : xla-pjrt (L2 JAX + L1 Pallas via HLO artifacts)\n\
         rounds            : {}\n\
         final test acc    : {:.2}%\n\
         final train loss  : {:.4}\n\
         uplink per agent  : {} bits/round (dimension-free)\n\
         total uplink      : {:.3e} bits\n\
         simulated time    : {:.1} s   (eq. 12, 0.1 Mbps lognormal)\n\
         simulated energy  : {:.2} J   (eq. 13, P_tx = 2 W)\n\
         host wall time    : {:.1} s",
        cfg.fed.method.name(),
        cfg.fed.rounds,
        last.test_acc * 100.0,
        last.train_loss,
        cfg.fed.method.uplink_bits(cfg.model.param_dim()),
        last.cum_bits,
        last.cum_sim_seconds,
        last.cum_energy_joules,
        host_s
    );
    history.write_csv(a.get("out"))?;
    println!("history written to {}", a.get("out"));
    Ok(())
}

//! Quickstart: the smallest end-to-end FedScalar run.
//!
//! Artifact-free (synthetic Digits twin + PureRust backend) so it works
//! immediately after `cargo build`:
//!
//!     cargo run --release --example quickstart
//!
//! For the real three-layer stack (PJRT-executed JAX/Pallas artifacts),
//! see `examples/e2e_train.rs`.

use fedscalar::algo::Method;
use fedscalar::config::ExperimentConfig;
use fedscalar::coordinator::Engine;
use fedscalar::rng::VDistribution;
use fedscalar::runtime::PureRustBackend;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The paper's Algorithm 1 with Rademacher projections, scaled down to
    // a ~20-second demo: N = 10 agents, K = 300 rounds.
    let mut cfg = ExperimentConfig::smoke();
    cfg.fed.num_agents = 10;
    cfg.fed.rounds = 300;
    cfg.fed.eval_every = 30;
    cfg.fed.alpha = 0.01;
    cfg.fed.method = Method::fedscalar(VDistribution::Rademacher, 1);

    let mut backend = PureRustBackend::new(&cfg.model);
    backend.set_shape(cfg.fed.local_steps, cfg.fed.batch_size);
    let mut engine = Engine::from_config(&cfg, Box::new(backend), 0)?;
    let history = engine.run()?;

    println!("\nround  train_loss  test_acc  cum_uplink_bits");
    for r in &history.records {
        println!(
            "{:>5}  {:>10.4}  {:>7.2}%  {:>14.0}",
            r.round,
            r.train_loss,
            r.test_acc * 100.0,
            r.cum_bits
        );
    }
    println!(
        "\nFedScalar uploaded {} bits/agent/round (two 32-bit scalars) — \
         FedAvg would have uploaded {} bits/agent/round for the same model.",
        cfg.fed.method.uplink_bits(cfg.model.param_dim()),
        Method::fedavg().uplink_bits(cfg.model.param_dim()),
    );
    Ok(())
}

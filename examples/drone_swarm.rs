//! Drone-swarm scenario from the paper's introduction: N = 100 embedded
//! agents collaboratively training a d ≈ 10^6-parameter DNN controller
//! under a 20-minute mission budget.
//!
//! The paper's §I argues that at this scale even a 1 Gbps TDMA uplink
//! blows the budget for full-model upload (3,200 s over K = 1,000
//! rounds), while 100 Mbps takes 8.9 h and 10 Mbps 88.9 h. This example
//! reproduces that arithmetic with the netsim substrate and contrasts it
//! with FedScalar's dimension-free payload — both analytically and with a
//! small simulated-fading run of the upload phase.
//!
//!     cargo run --release --example drone_swarm

use fedscalar::algo::Method;
use fedscalar::netsim::{energy_joules, upload_seconds, Channel, ChannelConfig, Schedule};
use fedscalar::rng::VDistribution;

const D: usize = 1_000_000; // controller parameters
const N: usize = 100; // drones
const K: usize = 1_000; // rounds
const MISSION_BUDGET_S: f64 = 20.0 * 60.0;

fn total_upload_time(bits_per_agent: u64, rate_bps: f64, schedule: Schedule) -> f64 {
    let one = upload_seconds(bits_per_agent, rate_bps);
    schedule.combine(&vec![one; N]) * K as f64
}

fn human(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1} h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{secs:.2} s")
    }
}

fn main() {
    let fedavg = Method::fedavg();
    let fedscalar = Method::fedscalar(VDistribution::Rademacher, 1);
    println!(
        "drone swarm: N={N} agents, d={D} parameters, K={K} rounds, mission budget {}\n",
        human(MISSION_BUDGET_S)
    );
    println!(
        "per-round uplink payload: FedAvg {} bits ({:.1} Mbit), FedScalar {} bits",
        fedavg.uplink_bits(D),
        fedavg.uplink_bits(D) as f64 / 1e6,
        fedscalar.uplink_bits(D)
    );

    println!("\ntotal upload time over the mission (TDMA, paper §I arithmetic):");
    println!(
        "{:<12} {:>16} {:>16} {:>10}",
        "uplink", "FedAvg", "FedScalar", "budget ok?"
    );
    for (name, rate) in [
        ("1 Gbps", 1e9),
        ("100 Mbps", 1e8),
        ("10 Mbps", 1e7),
        ("1 Mbps", 1e6),
    ] {
        let fa = total_upload_time(fedavg.uplink_bits(D), rate, Schedule::Tdma);
        let fs = total_upload_time(fedscalar.uplink_bits(D), rate, Schedule::Tdma);
        println!(
            "{:<12} {:>14}{} {:>14}{} {:>10}",
            name,
            human(fa),
            if fa > MISSION_BUDGET_S { "†" } else { " " },
            human(fs),
            if fs > MISSION_BUDGET_S { "†" } else { " " },
            if fs <= MISSION_BUDGET_S { "fedscalar" } else { "neither" }
        );
    }

    // paper anchors: 1 Gbps TDMA = 3,200 s; 100 Mbps = 8.9 h; 10 Mbps = 88.9 h
    let anchor = total_upload_time(fedavg.uplink_bits(D), 1e9, Schedule::Tdma);
    assert!((anchor - 3_200.0).abs() < 1.0, "paper anchor: {anchor}");

    // simulated upload phase with lognormal fading at 10 Mbps, one round
    let mut channel = Channel::new(
        ChannelConfig {
            nominal_bps: 1e7,
            sigma: 0.3,
        },
        0,
    );
    let mut per_agent = Vec::with_capacity(N);
    let mut round_energy = 0.0;
    for _ in 0..N {
        let rate = channel.sample_rate_bps();
        per_agent.push(upload_seconds(fedscalar.uplink_bits(D), rate));
        round_energy += energy_joules(2.0, fedscalar.uplink_bits(D), rate);
    }
    println!(
        "\nsimulated FedScalar upload phase @10 Mbps faded TDMA: {:.2} ms/round, {:.3} mJ/round (all {N} drones)",
        Schedule::Tdma.combine(&per_agent) * 1e3,
        round_energy * 1e3
    );
    println!(
        "the swarm's whole {K}-round mission uploads {:.1} kbit total per drone — \
         less than ONE FedAvg round ({:.1} Mbit).",
        (fedscalar.uplink_bits(D) * K as u64) as f64 / 1e3,
        fedavg.uplink_bits(D) as f64 / 1e6
    );
}
